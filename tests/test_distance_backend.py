"""Distance-backend dispatch tests.

Parity: the Pallas backend (interpret mode in this CPU container) must
match the XLA tensordot backend to 1e-4 on random pytrees — both as raw
(n, n) distances and through ``distributed_aggregate`` for every
distance-based GAR.  ``"auto"`` must resolve to the clean XLA fallback
off-TPU.  The shard-mapped path runs in an 8-device subprocess (same
pattern as tests/test_dist.py) and is pinned against the unsharded
result.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.robust import (distributed_aggregate,
                               pairwise_sq_dists_tree,
                               resolve_distance_backend)
from repro.kernels import pairwise_gram, pairwise_gram_tree
from repro.kernels.pairwise_gram import resolve_interpret
from repro.kernels.ref import pairwise_gram_ref

KEY = jax.random.PRNGKey(11)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_tree(n, key=KEY, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"a": {"w": jax.random.normal(k1, (n, 8, 16)).astype(dtype)},
            "b": jax.random.normal(k2, (n, 130)).astype(dtype),  # pads
            "c": jax.random.normal(k3, (n, 2, 3, 4)).astype(dtype),
            "d": jax.random.normal(k4, (n, 5)).astype(dtype)}


class TestBackendParity:
    @pytest.mark.parametrize("n", [5, 11, 16])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dists_pallas_matches_xla(self, n, dtype):
        tree = _random_tree(n, jax.random.fold_in(KEY, n), dtype)
        xla = pairwise_sq_dists_tree(tree, distance_backend="xla")
        pal = pairwise_sq_dists_tree(tree, distance_backend="pallas",
                                     interpret=True)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(pal, xla, rtol=tol, atol=tol)

    def test_tree_kernel_matches_flat_ref(self):
        tree = _random_tree(9)
        flat = jnp.concatenate(
            [l.reshape(9, -1) for l in jax.tree_util.tree_leaves(tree)], 1)
        np.testing.assert_allclose(
            pairwise_gram_tree(tree, interpret=True),
            pairwise_gram_ref(flat), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("gar", ["krum", "geomed", "multikrum",
                                     "brute", "bulyan-krum",
                                     "bulyan-geomed"])
    def test_aggregate_pallas_matches_xla(self, gar):
        n, f = 11, 2
        tree = _random_tree(n)
        a_x, r_x = distributed_aggregate(tree, f, gar,
                                         distance_backend="xla")
        a_p, r_p = distributed_aggregate(tree, f, gar,
                                         distance_backend="pallas")
        for x, p in zip(jax.tree_util.tree_leaves(a_x),
                        jax.tree_util.tree_leaves(a_p)):
            np.testing.assert_allclose(p, x, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(r_p.selected, r_x.selected,
                                   rtol=1e-4, atol=1e-4)


class TestAutoFallback:
    def test_auto_resolves_to_xla_off_tpu(self):
        assert jax.default_backend() != "tpu"  # this container
        assert resolve_distance_backend("auto") == "xla"
        # with or without a mesh: off-TPU auto is always the XLA path
        from repro.dist.mesh import make_host_mesh
        assert resolve_distance_backend(
            "auto", make_host_mesh((1, 1))) == "xla"

    def test_auto_aggregate_runs_and_matches(self):
        tree = _random_tree(7)
        a_auto, _ = distributed_aggregate(tree, 1, "krum",
                                          distance_backend="auto")
        a_xla, _ = distributed_aggregate(tree, 1, "krum",
                                         distance_backend="xla")
        for a, x in zip(jax.tree_util.tree_leaves(a_auto),
                        jax.tree_util.tree_leaves(a_xla)):
            np.testing.assert_array_equal(a, x)

    def test_bad_backend_rejected(self):
        with pytest.raises(ValueError, match="distance_backend"):
            pairwise_sq_dists_tree(_random_tree(5),
                                   distance_backend="cuda")

    def test_interpret_default_follows_backend(self):
        # the satellite fix: no explicit interpret under jit must NOT
        # mean interpret=True on TPU — the default resolves per backend
        assert resolve_interpret(None) == (jax.default_backend() != "tpu")
        g = jax.random.normal(KEY, (6, 300))
        np.testing.assert_allclose(pairwise_gram(g), pairwise_gram_ref(g),
                                   rtol=1e-4, atol=1e-4)

    def test_omniscient_linf_direction_anti(self):
        from repro.dist.robust import inject_byzantine
        n, f = 7, 2
        tree = _random_tree(n)
        out = inject_byzantine(tree, f, "omniscient_linf", gamma=2.0,
                               direction="anti")
        for lo, li in zip(jax.tree_util.tree_leaves(out),
                          jax.tree_util.tree_leaves(tree)):
            m = np.mean(np.asarray(li[:n - f], np.float32), axis=0)
            e = np.where(m == 0, 1.0, -np.sign(m))
            np.testing.assert_allclose(np.asarray(lo[n - f]), m + 2.0 * e,
                                       rtol=1e-5, atol=1e-6)

    def test_non_distance_gars_ignore_backend(self):
        tree = _random_tree(7)
        for backend in ("xla", "pallas", "auto"):
            a, _ = distributed_aggregate(tree, 1, "cwmed",
                                         distance_backend=backend)
            w, _ = distributed_aggregate(tree, 1, "cwmed")
            for x, y in zip(jax.tree_util.tree_leaves(a),
                            jax.tree_util.tree_leaves(w)):
                np.testing.assert_array_equal(x, y)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.mesh import make_host_mesh
    from repro.dist.robust import (distributed_aggregate,
                                   pairwise_sq_dists_tree)

    assert jax.device_count() == 8
    mesh = make_host_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    n = 8
    # "v" (trailing dim 5, indivisible by the 2-way model axis) enters
    # shard_map replicated — its partial must be summed exactly once,
    # not psum'd (the double-count regression)
    k4 = jax.random.fold_in(key, 4)
    tree = {"a": {"w": jax.random.normal(k1, (n, 8, 16))},
            "b": jax.random.normal(k2, (n, 64)),
            "c": jax.random.normal(k3, (n, 2, 3, 4)),
            "v": jax.random.normal(k4, (n, 5))}
    ref = pairwise_sq_dists_tree(tree)           # xla, unsharded
    ref_agg, _ = distributed_aggregate(tree, 1, "krum")

    # grads laid out as the train step produces them: worker axis on data
    sharded = jax.tree_util.tree_map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))), tree)
    with mesh:
        dists = jax.jit(lambda t: pairwise_sq_dists_tree(
            t, distance_backend="pallas", mesh=mesh, interpret=True))(
                sharded)
        agg = jax.jit(lambda t: distributed_aggregate(
            t, 1, "krum", distance_backend="pallas", mesh=mesh)[0])(
                sharded)

    agg_diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in
                 zip(jax.tree_util.tree_leaves(agg),
                     jax.tree_util.tree_leaves(ref_agg))]
    print(json.dumps({
        "devices": jax.device_count(),
        "dist_diff": float(jnp.max(jnp.abs(dists - ref))),
        "agg_diff": max(agg_diffs),
    }))
""")


@pytest.mark.slow
def test_shard_map_backend_matches_unsharded():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["dist_diff"] < 1e-4
    assert out["agg_diff"] < 1e-4
