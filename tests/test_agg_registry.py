"""Registry-layer tests: every rule resolves through one resolver, the
dense (flat) and distributed (tree) paths of each rule agree on identical
data, the merged spec serves both historic call forms, and the stateful
buffered rules actually depend on their carried history."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import (AggSpec, AggState, check_quorum, init_state, quorum,
                       resolve_rule, rule_names)
from repro.core import pytree as pt
from repro.dist.robust import distributed_aggregate
from repro.dist.train import DistByzantineSpec
from repro.training import ByzantineSpec

KEY = jax.random.PRNGKey(7)

# every stateless name the registry serves, incl. the composite family
STATELESS = ["average", "cwmed", "trimmed_mean", "krum", "geomed",
             "multikrum", "brute", "centered_clip", "bulyan-krum",
             "bulyan-geomed"]
STATEFUL = ["buffered-cwmed", "buffered-krum", "buffered-bulyan-krum",
            "centered_clip_momentum"]


def _stacked_tree(n, key=KEY):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": {"w": jax.random.normal(k1, (n, 8, 16))},
            "b": jax.random.normal(k2, (n, 64)),
            "c": jax.random.normal(k3, (n, 2, 3, 4))}


class TestResolver:
    def test_every_historic_name_resolves(self):
        for name in STATELESS + STATEFUL:
            rule = resolve_rule(name)
            assert rule.dense_fn is not None, name

    def test_registry_lists_base_rules(self):
        assert {"average", "krum", "multikrum", "geomed", "brute", "cwmed",
                "trimmed_mean", "centered_clip"} <= set(rule_names())

    def test_unknown_name_raises_keyerror(self):
        with pytest.raises(KeyError, match="unknown GAR"):
            resolve_rule("no-such-rule")

    def test_composites_are_cached(self):
        assert resolve_rule("bulyan-krum") is resolve_rule("bulyan-krum")
        assert (resolve_rule("buffered-cwmed")
                is resolve_rule("buffered-cwmed"))
        # a different window is a different rule
        assert (resolve_rule("buffered-cwmed", history_window=2)
                is not resolve_rule("buffered-cwmed", history_window=3))

    def test_old_get_gar_delegates(self):
        from repro.core import get_gar
        assert get_gar("krum") is resolve_rule("krum").dense_fn

    def test_quorums_unchanged(self):
        assert quorum("krum", 2) == 7
        assert quorum("bulyan-krum", 2) == 11
        assert quorum("buffered-krum", 2) == 7  # base's quorum

    def test_buffered_needs_stateless_base(self):
        with pytest.raises(KeyError, match="stateless base"):
            resolve_rule("buffered-centered_clip_momentum")


class TestSpecUnification:
    def test_old_names_are_one_type(self):
        assert ByzantineSpec is AggSpec
        assert DistByzantineSpec is AggSpec

    def test_both_validate_forms_work(self):
        ByzantineSpec(n_workers=15, f=3, gar="krum").validate()
        DistByzantineSpec(f=3, gar="krum").validate(15)

    def test_quorum_messages_agree(self):
        msgs = []
        for call in (lambda: ByzantineSpec(n_workers=6, f=3,
                                           gar="krum").validate(),
                     lambda: DistByzantineSpec(f=3, gar="krum").validate(6),
                     lambda: check_quorum("krum", 6, 3)):
            with pytest.raises(ValueError) as e:
                call()
            msgs.append(str(e.value))
        assert len(set(msgs)) == 1, msgs
        assert "krum requires n >= 9 for f=3, got n=6" in msgs[0]

    def test_spec_is_frozen_and_replaceable(self):
        spec = AggSpec(f=2, gar="bulyan-krum")
        assert dataclasses.replace(spec, gar="krum").gar == "krum"
        with pytest.raises(dataclasses.FrozenInstanceError):
            spec.f = 3

    def test_sharded_validate_requires_tree_impl(self):
        """Only the *explicit* distributed opt-in rejects tree-less
        rules: bulyan-brute is fine on the flat path, rejected on the
        sharded one (its phase 1 needs the gradients, not just
        distances)."""
        AggSpec(n_workers=7, f=1, gar="bulyan-brute").validate()
        with pytest.raises(KeyError, match="distance-only"):
            DistByzantineSpec(f=1, gar="bulyan-brute").validate(
                7, distributed=True)

    @pytest.mark.parametrize("gar", ["bulyan-brute", "stale-bulyan-brute",
                                     "bulyan-cwmed"])
    def test_flat_validate_with_explicit_n_stays_flat(self, gar):
        """Regression: ``validate(n)`` used to infer ``distributed``
        from ``n_workers is not None``, wrongly demanding a tree
        implementation from flat specs validated with an explicit
        worker count."""
        AggSpec(f=1, gar=gar).validate(8)           # must not raise
        with pytest.raises(KeyError, match="bulyan"):
            AggSpec(f=1, gar=gar).validate(8, distributed=True)

    def test_distributed_keyerror_messages(self):
        """Both canonical distributed KeyError texts survive: the
        bulyan-family hint and the generic no-tree-implementation."""
        with pytest.raises(KeyError,
                           match="needs a distance-only base"):
            check_quorum("stale-bulyan-brute", 9, 1, distributed=True)
        rule_names()  # populate the registry
        from repro.agg.registry import RULES
        treeless = [n for n, r in RULES.items() if r.tree_fn is None]
        for name in treeless:
            with pytest.raises(KeyError,
                               match="no distributed"):
                check_quorum(name, resolve_rule(name).min_n(1), 1,
                             distributed=True)


class TestDenseTreeParity:
    """Every registered rule produces identical output via the core dense
    path and dist.distributed_aggregate on a stacked pytree."""

    @pytest.mark.parametrize("gar", STATELESS)
    def test_stateless_parity(self, gar):
        n, f = 11, 2
        tree = _stacked_tree(n)
        rule = resolve_rule(gar)
        agg, _ = distributed_aggregate(tree, f, gar)
        flat, ctx = pt.stack_flatten(tree)
        want = pt.unflatten(rule.dense_fn(flat, f).gradient, ctx)
        for a, w in zip(jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(a, w, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("gar", STATEFUL)
    def test_stateful_parity_across_steps(self, gar):
        n, f = 11, 2
        rule = resolve_rule(gar)
        trees = [_stacked_tree(n, jax.random.PRNGKey(s)) for s in range(3)]
        flat0, ctx = pt.stack_flatten(trees[0])
        dense_state = init_state(rule, flat0)
        tree_state = None
        for tree in trees:
            flat, ctx = pt.stack_flatten(tree)
            dres, dense_state = rule.dense_fn(flat, f, dense_state)
            agg, _, tree_state = distributed_aggregate(
                tree, f, gar, state=tree_state)
            want = pt.unflatten(dres.gradient, ctx)
            for a, w in zip(jax.tree_util.tree_leaves(agg),
                            jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(a, w, rtol=1e-4, atol=1e-5)
        assert int(dense_state.step) == int(tree_state.step) == 3


class TestBufferedStatefulness:
    def test_same_inputs_different_history_different_output(self):
        """The new capability in one assertion: a buffered rule's output
        on identical submissions depends on the carried history."""
        n, f = 11, 2
        rule = resolve_rule("buffered-cwmed")
        g = jax.random.normal(jax.random.PRNGKey(0), (n, 32))
        other = 3.0 + jax.random.normal(jax.random.PRNGKey(1), (n, 32))

        fresh = init_state(rule, g)
        res_fresh, _ = rule.dense_fn(g, f, fresh)

        # absorb a different submission first -> different history
        warm = init_state(rule, g)
        _, warm = rule.dense_fn(other, f, warm)
        res_warm, _ = rule.dense_fn(g, f, warm)

        assert not np.allclose(res_fresh.gradient, res_warm.gradient)
        # the window mean pulls the output toward the absorbed history
        np.testing.assert_allclose(
            res_warm.gradient,
            np.median(np.asarray((g + other) / 2.0), axis=0),
            rtol=1e-4, atol=1e-5)

    def test_window_ring_buffer_evicts(self):
        """After window W more steps the old history is fully evicted."""
        n, f, w = 9, 1, 2
        rule = resolve_rule("buffered-cwmed", history_window=w)
        g = jax.random.normal(jax.random.PRNGKey(2), (n, 16))
        poison = 100.0 + jnp.zeros((n, 16))
        state = init_state(rule, g)
        _, state = rule.dense_fn(poison, f, state)
        for _ in range(w):
            res, state = rule.dense_fn(g, f, state)
        np.testing.assert_allclose(res.gradient,
                                   np.median(np.asarray(g), axis=0),
                                   rtol=1e-4, atol=1e-5)

    def test_centered_clip_momentum_carries_center(self):
        n, f = 9, 1
        rule = resolve_rule("centered_clip_momentum")
        g = jax.random.normal(jax.random.PRNGKey(3), (n, 16))
        far = 50.0 + jax.random.normal(jax.random.PRNGKey(4), (n, 16))
        s0 = init_state(rule, g)
        _, s_far = rule.dense_fn(far, f, s0)
        res_warm, _ = rule.dense_fn(g, f, s_far)
        res_cold, _ = rule.dense_fn(g, f, init_state(rule, g))
        # warm start from the far center clips toward it -> different agg
        assert not np.allclose(res_warm.gradient, res_cold.gradient)

    def test_bare_array_tree_self_initializes_correctly(self):
        """A bare (n, d) array is a valid pytree for the distributed
        engine; the self-initialized state must use the tree (tuple)
        buffer layout and the result must match the dense rule."""
        n, f = 9, 1
        g = jax.random.normal(jax.random.PRNGKey(5), (n, 32))
        rule = resolve_rule("buffered-cwmed")
        agg, _, state = distributed_aggregate(g, f, "buffered-cwmed")
        assert agg.shape == (32,)
        assert isinstance(state.history, tuple)
        dres, _ = rule.dense_fn(g, f, init_state(rule, g))
        np.testing.assert_allclose(agg, dres.gradient, rtol=1e-4,
                                   atol=1e-5)

    def test_state_is_a_jitable_carry(self):
        n, f = 9, 1
        rule = resolve_rule("buffered-krum")
        tree = _stacked_tree(n)
        state = init_state(rule, tree)

        @jax.jit
        def step(t, s):
            agg, _, s = distributed_aggregate(t, f, "buffered-krum",
                                              state=s)
            return agg, s

        _, state = step(tree, state)
        _, state = step(tree, state)
        assert int(state.step) == 2
        assert isinstance(state, AggState)


class TestTrainerIntegration:
    def test_buffered_rule_through_byzantine_trainer(self):
        """Acceptance: a stateful buffered-* rule runs through
        ByzantineTrainer with its AggState carried across steps."""
        from repro.data import ByzantineBatcher
        from repro.models import simple
        from repro.optim import get_optimizer
        from repro.training import ByzantineTrainer

        def loss_fn(params, x, y):
            return simple.classification_loss(
                simple.mnist_mlp_forward(params, x), y, params)

        spec = ByzantineSpec(n_workers=9, f=1, gar="buffered-cwmed",
                             attack="signflip", history_window=3)
        tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.1), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 16), 4)
        assert int(tr.agg_state.step) == 4
        assert len(tr.history) == 4

    def test_momentum_center_survives_attack_until_flip(self):
        """attack_until resizes only per-worker history buffers; the
        row-count-independent centered_clip_momentum center (the whole
        point of the momentum defense) must survive the flip."""
        from repro.data import ByzantineBatcher
        from repro.models import simple
        from repro.optim import get_optimizer
        from repro.training import ByzantineTrainer

        def loss_fn(params, x, y):
            return simple.classification_loss(
                simple.mnist_mlp_forward(params, x), y, params)

        spec = ByzantineSpec(n_workers=9, f=1,
                             gar="centered_clip_momentum",
                             attack="signflip")
        tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.1), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 16), 4,
               attack_until=2)
        assert int(tr.agg_state.step) == 4  # never re-zeroed
        assert float(jnp.sum(jnp.abs(tr.agg_state.center))) > 0.0

    def test_buffered_rule_through_dist_train_step(self):
        """Acceptance: the same rule through the dist make_train_step."""
        from repro.configs import get_reduced
        from repro.dist.train import (init_agg_state, make_loss_fn,
                                      make_train_step)
        from repro.models import init_model
        from repro.optim import get_optimizer

        cfg = get_reduced("llama3_2_3b")
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt = get_optimizer("momentum", 1e-2)
        spec = DistByzantineSpec(f=0, gar="buffered-cwmed",
                                 history_window=2)
        step = jax.jit(make_train_step(cfg, spec, opt))
        n, b, s = 4, 2, 16
        batch = {
            "tokens": jax.random.randint(KEY, (n, b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(KEY, (n, b, s), 0, cfg.vocab_size),
        }
        agg_state = init_agg_state(spec, params, n)
        assert int(agg_state.step) == 0
        params, opt_state, m, agg_state = step(params, opt.init(params),
                                               batch, agg_state)
        params, opt_state, m, agg_state = step(params, opt_state, batch,
                                               agg_state)
        assert int(agg_state.step) == 2
        assert bool(jnp.isfinite(m["loss"]))
