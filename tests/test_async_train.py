"""Asynchronous bounded-staleness runtime tests.

Pins the three contracts of ``repro.dist.async_train`` +
``repro.agg.staleness``:

  * tau = 0 degenerates to synchrony: the async step (flat and sharded
    builders) is bitwise-equal to the synchronous step on identical
    inputs — attacks and ``stale-*`` rules included;
  * the GradientBus respects its bounded-staleness ring: versions wrap
    through the delivery cycle, staleness never exceeds tau, slots hold
    exactly the gradient delivered at their version step;
  * the delay-exploiting ``stale_replay`` attack defeats plain
    ``average`` but not ``stale-bulyan-krum`` (nor ``stale-krum``) on
    the miniature MNIST protocol.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import init_state, quorum, resolve_rule, rule_names
from repro.core import pytree as pt
from repro.data import ByzantineBatcher
from repro.data.synthetic import mnist_like
from repro.dist.async_train import (GradientBus, delivery_mask,
                                    init_async_state, init_bus,
                                    make_async_train_step, resolve_tau,
                                    update_bus)
from repro.dist.robust import distributed_aggregate
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import (AsyncByzantineTrainer, ByzantineSpec,
                            init_flat_async_state,
                            make_async_byzantine_step, make_byzantine_step)

KEY = jax.random.PRNGKey(11)


def mnist_loss(params, x, y):
    return simple.classification_loss(
        simple.mnist_mlp_forward(params, x), y, params)


# ---------------------------------------------------------------------------
# bus mechanics
# ---------------------------------------------------------------------------

class TestBusMechanics:
    def test_resolve_tau_forms(self):
        np.testing.assert_array_equal(resolve_tau(3, 4), [3, 3, 3, 3])
        np.testing.assert_array_equal(resolve_tau((0, 1, 2, 8), 4),
                                      [0, 1, 2, 8])
        with pytest.raises(ValueError):
            resolve_tau(-1, 4)
        with pytest.raises(ValueError):
            resolve_tau((1, 2), 4)
        with pytest.raises(ValueError):
            resolve_tau((-1, 2, 0, 1), 4)   # per-worker bounds too

    def test_tau0_delivers_everyone_every_step(self):
        tau = resolve_tau(0, 9)
        versions = jnp.zeros((9,), jnp.int32)
        for sched in ("fixed", "random"):
            for t in range(5):
                m = delivery_mask(t, versions, tau, sched)
                assert bool(jnp.all(m)), (sched, t)

    def test_ring_wraparound_bounded_staleness(self):
        """Across several full delivery cycles (the ring wrapping), every
        slot holds exactly the gradient delivered at its version step
        and staleness never exceeds the per-worker bound."""
        n, d = 6, 8
        tau = resolve_tau((0, 1, 2, 3, 3, 2), n)
        base = jax.random.normal(KEY, (n, d))
        bus = init_bus(base)
        payloads = []
        for t in range(14):   # > 3 cycles of the largest tau+1
            fresh = base * (t + 1)          # step-tagged payload
            payloads.append(np.asarray(fresh))
            m = delivery_mask(t, bus.versions, tau, "fixed")
            bus = update_bus(bus, fresh, t, m)
            stal = t - np.asarray(bus.versions)
            assert stal.min() >= 0
            assert (stal <= np.asarray(tau)).all(), (t, stal)
            # slot w == the gradient computed at step versions[w]
            vers = np.asarray(bus.versions)
            want = np.stack([payloads[vers[w]][w] for w in range(n)])
            np.testing.assert_array_equal(np.asarray(bus.grads), want)
        # a tau=0 worker is always fresh; a tau=3 worker actually wrapped
        assert int(bus.versions[0]) == 13
        versions_seen = set()
        bus2 = init_bus(base)
        for t in range(8):
            m = delivery_mask(t, bus2.versions, tau, "fixed")
            bus2 = update_bus(bus2, base, t, m)
            versions_seen.add(int(bus2.versions[3]))
        assert len(versions_seen) > 1   # the tau=3 slot re-arms mid-run

    def test_random_schedule_respects_bound(self):
        n = 7
        tau = resolve_tau(3, n)
        bus = init_bus(jnp.zeros((n, 4)))
        for t in range(25):
            m = delivery_mask(t, bus.versions, tau, "random", seed=5)
            bus = update_bus(bus, jnp.zeros((n, 4)), t, m)
            assert (t - np.asarray(bus.versions) <= 3).all()

    def test_unknown_schedule_raises(self):
        with pytest.raises(ValueError, match="async_schedule"):
            delivery_mask(0, jnp.zeros((3,), jnp.int32),
                          resolve_tau(1, 3), "lossy")


# ---------------------------------------------------------------------------
# stale-<base> through the registry
# ---------------------------------------------------------------------------

class TestStaleRules:
    def test_stale_wraps_every_registered_base(self):
        """Acceptance: stale-<base> resolves for every registered rule
        (plus the composite families) with no per-rule forks, and with
        an all-fresh bus reproduces the base bitwise."""
        n, f, d = 11, 2, 24
        g = jax.random.normal(KEY, (n, d))
        bases = rule_names() + ["bulyan-krum", "buffered-cwmed"]
        for base_name in bases:
            rule = resolve_rule(f"stale-{base_name}")
            assert rule.stateful and "bus" in rule.state_fields, base_name
            assert rule.min_n(f) == quorum(base_name, f)
            base = resolve_rule(base_name)
            state = init_state(rule, g)
            res, state2 = rule.dense_fn(g, f, state)
            if base.stateful:
                bres, _ = base.dense_fn(g, f, init_state(base, g))
            else:
                bres = base.dense_fn(g, f)
            np.testing.assert_array_equal(np.asarray(res.gradient),
                                          np.asarray(bres.gradient),
                                          err_msg=base_name)
            assert int(state2.step) == 1, base_name

    def test_weight_schedules(self):
        from repro.agg import stale_weights
        s = jnp.asarray([0, 1, 3])
        np.testing.assert_allclose(stale_weights(s, "inv"),
                                   [1.0, 0.5, 0.25])
        np.testing.assert_allclose(stale_weights(s, "exp", lam=1.0),
                                   np.exp([0.0, -1.0, -3.0]), rtol=1e-6)
        with pytest.raises(ValueError, match="staleness weight"):
            stale_weights(s, "poly")

    def test_staleness_reweights_average(self):
        n, f, d = 8, 1, 16
        g = jax.random.normal(KEY, (n, d))
        rule = resolve_rule("stale-average")
        state = init_state(rule, g)
        versions = jnp.asarray([4] * (n - 1) + [0], jnp.int32)
        state = state._replace(step=jnp.asarray(4, jnp.int32),
                               bus=state.bus._replace(versions=versions))
        res, _ = rule.dense_fn(g, f, state)
        w = np.ones(n)
        w[-1] = 1.0 / 5.0          # staleness 4 under the inv schedule
        want = (np.asarray(g) * w[:, None]).mean(0)
        np.testing.assert_allclose(np.asarray(res.gradient), want,
                                   rtol=1e-4, atol=1e-6)

    @pytest.mark.parametrize("gar", ["stale-cwmed", "stale-krum",
                                     "stale-bulyan-krum",
                                     "stale-exp-trimmed_mean",
                                     "stale-buffered-cwmed"])
    def test_dense_tree_parity_under_staleness(self, gar):
        n, f = 11, 2
        k1, k2 = jax.random.split(KEY)
        tree = {"a": jax.random.normal(k1, (n, 4, 6)),
                "b": jax.random.normal(k2, (n, 32))}
        versions = jnp.asarray([0, 1, 2, 3, 3, 3, 2, 1, 0, 3, 2],
                               jnp.int32)
        rule = resolve_rule(gar)
        flat, ctx = pt.stack_flatten(tree)
        ds = init_state(rule, flat)
        ds = ds._replace(step=jnp.asarray(3, jnp.int32),
                         bus=ds.bus._replace(versions=versions))
        dres, _ = rule.dense_fn(flat, f, ds)
        ts = init_state(rule, tree, flat=False)
        ts = ts._replace(step=jnp.asarray(3, jnp.int32),
                         bus=ts.bus._replace(versions=versions))
        agg, _, ts2 = distributed_aggregate(tree, f, gar, state=ts)
        want = pt.unflatten(dres.gradient, ctx)
        for a, w in zip(jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                       rtol=1e-4, atol=1e-5)
        assert int(ts2.step) == 4

    def test_stale_nesting_rejected(self):
        with pytest.raises(KeyError, match="nest"):
            resolve_rule("stale-stale-krum")

    def test_unknown_base_propagates(self):
        with pytest.raises(KeyError, match="unknown GAR"):
            resolve_rule("stale-no-such-rule")

    def test_dashless_stale_typo_rejected(self):
        """The stale_replay *attack* name (or a stalekrum typo) passed
        as a GAR must error loudly, not resolve to stale-average."""
        for typo in ("stale_replay", "stalekrum", "stale"):
            with pytest.raises(KeyError, match="unknown GAR"):
                resolve_rule(typo)


# ---------------------------------------------------------------------------
# tau = 0 reproduces the synchronous steps exactly
# ---------------------------------------------------------------------------

class TestTau0Equivalence:
    @pytest.mark.parametrize("gar,attack", [
        ("krum", "omniscient_lp"), ("stale-bulyan-krum", "none")])
    def test_flat_step_bitwise(self, gar, attack):
        f = 3 if attack != "none" else 0
        n_h = 12
        base = gar.replace("stale-", "")
        spec = ByzantineSpec(
            n_workers=n_h + f, f=f, gar=gar, attack=attack,
            attack_kwargs=(("gar_name", "krum"),) if f else (),
            async_tau=0)
        sspec = ByzantineSpec(
            n_workers=n_h + f, f=f, gar=base, attack=attack,
            attack_kwargs=spec.attack_kwargs)
        params = simple.init_mnist_mlp(KEY)
        opt = get_optimizer("sgd", 0.1)
        sync = jax.jit(make_byzantine_step(mnist_loss, opt, sspec))
        astep = jax.jit(make_async_byzantine_step(mnist_loss, opt, spec))
        x, y = ByzantineBatcher("mnist", n_h, 16).batch(0)
        x, y = jnp.asarray(x), jnp.asarray(y)
        k = jax.random.PRNGKey(9)
        p1, o1, m1 = sync(params, opt.init(params), x, y, k)
        st = init_flat_async_state(spec, params)
        p2, o2, m2, st2 = astep(params, opt.init(params), x, y, k, st)
        for a, b in zip(jax.tree_util.tree_leaves((p1, o1)),
                        jax.tree_util.tree_leaves((p2, o2))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for key in m1:
            np.testing.assert_array_equal(np.asarray(m1[key]),
                                          np.asarray(m2[key]))
        assert float(m2["staleness_max"]) == 0.0
        assert int(st2.step) == 1

    @pytest.mark.parametrize("gar,attack,f", [
        ("krum", "signflip", 2), ("stale-krum", "stale_replay", 2)])
    def test_dist_step_bitwise(self, gar, attack, f):
        """The sharded builder (executed unsharded — the identical step
        function runs under GSPMD, see tests/test_dist.py) at tau=0
        equals the synchronous make_train_step bitwise."""
        from repro.configs import get_reduced
        from repro.dist.train import DistByzantineSpec, make_train_step
        from repro.models import init_model

        cfg = get_reduced("llama3_2_3b")
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt = get_optimizer("momentum", 1e-2)
        n, b, s = 7, 2, 16
        batch = {"tokens": jax.random.randint(KEY, (n, b, s), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (n, b, s), 0,
                                              cfg.vocab_size)}
        spec = DistByzantineSpec(f=f, gar=gar, attack=attack, async_tau=0)
        sspec = DistByzantineSpec(f=f, gar=gar.replace("stale-", ""),
                                  attack=attack)
        sync = jax.jit(make_train_step(cfg, sspec, opt))
        astep = jax.jit(make_async_train_step(cfg, spec, opt))
        p1, o1, m1 = sync(params, opt.init(params), batch)
        st = init_async_state(spec, params, n)
        p2, o2, m2, st2 = astep(params, opt.init(params), batch, st)
        for a, bb in zip(jax.tree_util.tree_leaves((p1, o1)),
                         jax.tree_util.tree_leaves((p2, o2))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
        for key in m1:
            # params/opt-state are bitwise; metrics are compared at ulp
            # tolerance (the two programs may fuse the honest-mean
            # diagnostic differently)
            np.testing.assert_allclose(np.asarray(m1[key]),
                                       np.asarray(m2[key]),
                                       rtol=0, atol=1e-6)
        assert float(m2["delivered"]) == n
        assert int(st2.step) == 1


# ---------------------------------------------------------------------------
# the delay attacks vs the staleness-aware defenses
# ---------------------------------------------------------------------------

class TestStaleReplayDefense:
    def _run(self, gar, attack, steps=40):
        spec = ByzantineSpec(n_workers=39, f=9, gar=gar, attack=attack,
                             async_tau=3,
                             attack_kwargs=(("scale", -4.0), ("hold", 4))
                             if attack == "stale_replay" else ())
        tr = AsyncByzantineTrainer(
            mnist_loss, simple.init_mnist_mlp(KEY),
            get_optimizer("sgd", fading_lr(1.0, 10000)), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 32, seed=1,
                                noise=0.5), steps)
        xe, ye = mnist_like(800, 10 ** 6, seed=0, noise=0.5)
        acc = float(simple.accuracy(
            simple.mnist_mlp_forward(tr.params, jnp.asarray(xe)),
            jnp.asarray(ye)))
        return acc, tr

    def test_stale_replay_defeats_average_not_stale_bulyan(self):
        acc_avg, tr_avg = self._run("average", "stale_replay")
        acc_bul, _ = self._run("stale-bulyan-krum", "stale_replay")
        assert acc_avg < 0.85, acc_avg          # poisoned
        assert acc_bul > 0.95, acc_bul          # defense holds
        # the replayed rows really ride the bus: byz weight in average
        assert tr_avg.history[-1]["byz_weight"] > 0.0

    def test_stale_krum_holds_too(self):
        acc, _ = self._run("stale-krum", "stale_replay")
        assert acc > 0.9, acc

    def test_clean_async_training_learns(self):
        spec = ByzantineSpec(n_workers=30, f=0, gar="stale-krum",
                             attack="none", async_tau=3)
        tr = AsyncByzantineTrainer(
            mnist_loss, simple.init_mnist_mlp(KEY),
            get_optimizer("sgd", fading_lr(1.0, 10000)), spec)
        tr.run(ByzantineBatcher("mnist", 30, 32, seed=1), 30)
        xe, ye = mnist_like(800, 10 ** 6, seed=0)
        acc = float(simple.accuracy(
            simple.mnist_mlp_forward(tr.params, jnp.asarray(xe)),
            jnp.asarray(ye)))
        assert acc > 0.9
        assert tr.history[-1]["staleness_mean"] > 0.0  # genuinely async

    def test_slow_drift_biases_average(self):
        """The drift integrates: average's deviation from the honest
        mean grows across steps while stale-bulyan's stays flat."""
        spec = ByzantineSpec(n_workers=39, f=9, gar="average",
                             attack="slow_drift", async_tau=3,
                             attack_kwargs=(("eps", 1.0),))
        tr = AsyncByzantineTrainer(
            mnist_loss, simple.init_mnist_mlp(KEY),
            get_optimizer("sgd", fading_lr(1.0, 10000)), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 32, seed=1), 30)
        devs = [h["agg_dev"] for h in tr.history]
        assert devs[-1] > 3 * max(devs[0], 1e-3)


# ---------------------------------------------------------------------------
# state plumbing
# ---------------------------------------------------------------------------

class TestAsyncStatePlumbing:
    def test_init_async_state_composes_with_eval_shape(self):
        from repro.configs import get_reduced
        from repro.dist.train import DistByzantineSpec
        from repro.models import init_model

        cfg = get_reduced("llama3_2_3b")
        params = jax.eval_shape(
            lambda: init_model(jax.random.PRNGKey(0), cfg))
        for gar in ("krum", "stale-bulyan-krum", "stale-buffered-cwmed"):
            spec = DistByzantineSpec(f=1, gar=gar, async_tau=2)
            st = jax.eval_shape(lambda: init_async_state(spec, params, 7))
            assert isinstance(st.bus, GradientBus)
            assert st.bus.versions.shape == (7,)
            assert st.step.dtype == jnp.int32

    def test_flat_state_always_carries_bus(self):
        params = simple.init_mnist_mlp(KEY)
        for gar in ("average", "stale-krum"):
            spec = ByzantineSpec(n_workers=9, f=1, gar=gar,
                                 attack="signflip", async_tau=1)
            st = init_flat_async_state(spec, params)
            assert isinstance(st.bus, GradientBus)
            assert st.bus.grads.shape[0] == 9
        spec = ByzantineSpec(n_workers=9, f=0, gar="average",
                             attack="none")
        st = init_flat_async_state(spec, params)
        assert st.bus.grads.shape[0] == 9   # clean mode: n_honest rows

    def test_async_state_is_a_jitable_carry(self):
        n, f, d = 9, 1, 12
        g = jax.random.normal(KEY, (n, d))
        rule = resolve_rule("stale-cwmed")
        state = init_state(rule, g)

        @jax.jit
        def one(x, s):
            res, s = rule.dense_fn(x, f, s)
            return res.gradient, s

        _, state = one(g, state)
        _, state = one(g, state)
        assert int(state.step) == 2


# ---------------------------------------------------------------------------
# bounded-staleness observability + restore hygiene
# ---------------------------------------------------------------------------

class TestStalenessExcessAndRestore:
    @pytest.mark.parametrize("tau", [0, 2])
    def test_fixed_schedule_never_exceeds_declared_bound(self, tau):
        """``metrics["staleness_excess"]`` must stay 0 for the whole run
        under a ``fixed`` schedule — the deterministic round-robin delay
        pattern is tautologically within its own declared tau (the gap
        the audit sweep also pins; a nonzero value here means the bus
        update and the delivery mask disagree about ages)."""
        from repro.configs import get_reduced
        from repro.dist.train import DistByzantineSpec
        from repro.models import init_model

        cfg = get_reduced("llama3_2_3b")
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt = get_optimizer("sgd", 1e-2)
        n, b, s = 7, 2, 16
        batch = {"tokens": jax.random.randint(KEY, (n, b, s), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (n, b, s), 0,
                                              cfg.vocab_size)}
        spec = DistByzantineSpec(f=0, gar="stale-krum", attack="none",
                                 async_tau=tau, async_schedule="fixed")
        astep = jax.jit(make_async_train_step(cfg, spec, opt))
        state = init_async_state(spec, params, n)
        opt_state = opt.init(params)
        for _ in range(2 * tau + 3):
            params, opt_state, m, state = astep(params, opt_state, batch,
                                                state)
            assert float(m["staleness_excess"]) == 0.0
            assert float(m["staleness_max"]) <= tau

    @pytest.mark.parametrize("name", ["stale-reputation-krum",
                                      "reputation-stale-krum"])
    def test_negative_restore_ages_clamp_through_reputation(self, name):
        """A bus restored with versions ahead of a zeroed step counter
        (the checkpoint-mismatch shape) yields *negative* slot ages.
        Both nesting orders must clamp the stale scale to 1 — bitwise
        the plain base at fresh reputation — instead of amplifying rows
        or pushing reputation out of [0, 1]."""
        n, f, d = 9, 2, 12
        rule = resolve_rule(name)
        g = jax.random.normal(KEY, (n, d), jnp.float32)
        state = init_state(rule, g)
        state = state._replace(bus=state.bus._replace(
            versions=jnp.full((n,), 5, jnp.int32)))  # step=0: age -5
        res, new_state = rule.dense_fn(g, f, state)
        assert bool(jnp.all(jnp.isfinite(res.gradient)))
        ref = resolve_rule("krum").dense_fn(g, f)
        np.testing.assert_array_equal(np.asarray(res.gradient),
                                      np.asarray(ref.gradient))
        rep = np.asarray(new_state.reputation)
        assert rep.min() >= 0.0 and rep.max() <= 1.0
