"""Substrate tests: optimizers, schedules, data determinism, checkpoints."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import (ByzantineBatcher, cifar_like, lm_batches,
                                  mnist_like)
from repro.optim import adam, fading_lr, get_optimizer, momentum, sgd


class TestOptimizers:
    def _quad(self, opt, steps=200):
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(steps):
            grads = {"w": 2 * params["w"]}  # d/dw w^2
            params, state = opt.update(grads, state, params)
        return float(jnp.max(jnp.abs(params["w"])))

    @pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.05),
                                         ("adam", 0.3), ("adamw", 0.3)])
    def test_minimizes_quadratic(self, name, lr):
        assert self._quad(get_optimizer(name, lr)) < 0.05

    def test_fading_lr_schedule(self):
        sched = fading_lr(1.0, 100.0)
        assert float(sched(jnp.asarray(0))) == pytest.approx(1.0)
        assert float(sched(jnp.asarray(100))) == pytest.approx(0.5)
        assert float(sched(jnp.asarray(900))) == pytest.approx(0.1)

    def test_bf16_params_fp32_accumulator(self):
        opt = momentum(0.1)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.init(params)
        assert state["m"]["w"].dtype == jnp.float32
        new, _ = opt.update({"w": jnp.ones((4,), jnp.bfloat16)}, state,
                            params)
        assert new["w"].dtype == jnp.bfloat16


class TestData:
    def test_determinism(self):
        a = mnist_like(32, 7, seed=1)
        b = mnist_like(32, 7, seed=1)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
        c = mnist_like(32, 8, seed=1)
        assert not np.array_equal(a[0], c[0])

    def test_shapes_and_ranges(self):
        x, y = mnist_like(16, 0)
        assert x.shape == (16, 784) and x.min() >= 0 and x.max() <= 1
        x, y = cifar_like(8, 0)
        assert x.shape == (8, 32, 32, 3)
        t, l = lm_batches(1000, 4, 32, 0)
        assert t.shape == (4, 32) and l.shape == (4, 32)
        assert t.max() < 1000
        # labels are next tokens
        full_t, full_l = lm_batches(1000, 4, 32, 5)
        np.testing.assert_array_equal(full_t[:, 1:], full_l[:, :-1])

    def test_lm_stream_is_learnable_structure(self):
        """The Markov stream must be predictable: successor entropy is
        bounded by log(branch) + noise, far below log(vocab)."""
        t, l = lm_batches(512, 64, 128, 0, branch=4)
        # count distinct successors per token in this sample
        from collections import defaultdict
        succ = defaultdict(set)
        for row_t, row_l in zip(t, l):
            for a, b in zip(row_t, row_l):
                succ[int(a)].add(int(b))
        avg = np.mean([len(v) for v in succ.values()])
        assert avg < 10  # vocab 512 would give ~dozens if unstructured

    def test_byzantine_batcher_worker_shapes(self):
        b = ByzantineBatcher("mnist", n_honest=5, per_worker=8)
        x, y = b.batch(0)
        assert x.shape == (5, 8, 784) and y.shape == (5, 8)
        # workers draw different samples
        assert not np.array_equal(x[0], x[1])


class TestCheckpoint:
    def test_roundtrip(self):
        params = {"a": {"w": jnp.arange(6.0).reshape(2, 3),
                        "b": jnp.ones((4,), jnp.bfloat16)},
                  "scale": jnp.asarray(2.5)}
        with tempfile.TemporaryDirectory() as td:
            save_checkpoint(td, params, step=42, metadata={"note": "t"})
            restored, step = load_checkpoint(td, params)
        assert step == 42
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_shape_mismatch_raises(self):
        params = {"w": jnp.ones((2, 2))}
        with tempfile.TemporaryDirectory() as td:
            save_checkpoint(td, params)
            with pytest.raises(ValueError):
                load_checkpoint(td, {"w": jnp.ones((3, 3))})

    def test_structure_mismatch_raises(self):
        params = {"w": jnp.ones((2,))}
        with tempfile.TemporaryDirectory() as td:
            save_checkpoint(td, params)
            with pytest.raises(ValueError):
                load_checkpoint(td, {"w": jnp.ones((2,)),
                                     "v": jnp.ones((2,))})
