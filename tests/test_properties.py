"""Hypothesis property tests for the system's invariants.

Key invariants from the paper:
  * Bulyan bracketing (the mechanism behind Proposition 2): with at most f
    Byzantine rows among n >= 4f+3, every output coordinate lies within the
    min/max of the *honest* workers' values at that coordinate.
  * Permutation equivariance: GARs must not depend on worker order (up to
    ties; we use generic float data).
  * Translation equivariance: GAR(G + c) = GAR(G) + c.
  * Attack containment: arbitrarily bad Byzantine rows cannot drag
    cwmed/trimmed-mean/bulyan outside the honest per-coordinate envelope.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import get_gar, select_indices

FLOATS = st.floats(min_value=-100.0, max_value=100.0, allow_nan=False,
                   width=32)


def _case(draw_n_min=7):
    return st.tuples(
        st.integers(min_value=1, max_value=3),     # f
        st.integers(min_value=0, max_value=6),     # extra workers
        st.integers(min_value=1, max_value=32),    # d
        st.integers(min_value=0, max_value=2 ** 31 - 1),
    )


@settings(max_examples=25, deadline=None)
@given(_case())
def test_bulyan_bracketed_by_honest_envelope(case):
    f, extra, d, seed = case
    n = 4 * f + 3 + extra
    rng = np.random.default_rng(seed)
    honest = rng.normal(size=(n - f, d)).astype(np.float32)
    byz = rng.normal(scale=1000.0, size=(f, d)).astype(np.float32)
    full = jnp.asarray(np.concatenate([honest, byz]))
    out = np.asarray(get_gar("bulyan-krum")(full, f).gradient)
    lo, hi = honest.min(0), honest.max(0)
    span = np.maximum(hi - lo, 1e-3)
    assert np.all(out >= lo - 1e-3 * span - 1e-4)
    assert np.all(out <= hi + 1e-3 * span + 1e-4)


@settings(max_examples=25, deadline=None)
@given(_case())
def test_coordinatewise_rules_bracketed(case):
    f, extra, d, seed = case
    n = 2 * f + 1 + extra
    rng = np.random.default_rng(seed)
    honest = rng.normal(size=(n - f, d)).astype(np.float32)
    byz = rng.normal(scale=1e6, size=(f, d)).astype(np.float32)
    full = jnp.asarray(np.concatenate([honest, byz]))
    lo, hi = honest.min(0), honest.max(0)
    for name in ("cwmed", "trimmed_mean"):
        out = np.asarray(get_gar(name)(full, f).gradient)
        assert np.all(out >= lo - 1e-4), name
        assert np.all(out <= hi + 1e-4), name


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from(["krum", "geomed", "cwmed", "trimmed_mean",
                        "average"]))
def test_permutation_equivariance(seed, name):
    rng = np.random.default_rng(seed)
    n, f, d = 11, 2, 16
    g = rng.normal(size=(n, d)).astype(np.float32)
    perm = rng.permutation(n)
    a = np.asarray(get_gar(name)(jnp.asarray(g), f).gradient)
    b = np.asarray(get_gar(name)(jnp.asarray(g[perm]), f).gradient)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_bulyan_permutation_weak_equivariance(seed):
    """Bulyan's recursion hits k = 1 Krum steps near the end (k = n_rem -
    f - 2 clamped), where mutually-nearest pairs tie *exactly*; which of
    the pair is selected is index-order dependent.  Both outcomes are
    valid Bulyan selections, so the guarantee we test is invariance of the
    output's honest-envelope containment, not bitwise equality."""
    rng = np.random.default_rng(seed)
    n, f, d = 11, 2, 16
    g = rng.normal(size=(n, d)).astype(np.float32)
    perm = rng.permutation(n)
    a = np.asarray(get_gar("bulyan-krum")(jnp.asarray(g), f).gradient)
    b = np.asarray(get_gar("bulyan-krum")(jnp.asarray(g[perm]), f).gradient)
    lo, hi = g.min(0), g.max(0)
    for out in (a, b):
        assert np.all(out >= lo - 1e-4)
        assert np.all(out <= hi + 1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.floats(min_value=-50, max_value=50, allow_nan=False),
       st.sampled_from(["krum", "geomed", "cwmed", "trimmed_mean",
                        "bulyan-krum", "average"]))
def test_translation_equivariance(seed, c, name):
    rng = np.random.default_rng(seed)
    n, f, d = 11, 2, 16
    g = rng.normal(size=(n, d)).astype(np.float32)
    a = np.asarray(get_gar(name)(jnp.asarray(g), f).gradient) + np.float32(c)
    b = np.asarray(get_gar(name)(jnp.asarray(g + np.float32(c)), f).gradient)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_selection_rules_reject_far_outliers(seed):
    rng = np.random.default_rng(seed)
    n, f, d = 11, 2, 32
    honest = rng.normal(scale=0.1, size=(n - f, d)).astype(np.float32)
    byz = 1e4 + rng.normal(size=(f, d)).astype(np.float32)
    full = jnp.asarray(np.concatenate([honest, byz]))
    for name in ("krum", "geomed"):
        sel = np.asarray(get_gar(name)(full, f).selected)
        assert sel[-f:].sum() == 0.0, name
    # Bulyan's selection may legitimately contain up to f Byzantine
    # vectors (a colluding far-away pair is mutually close); phase 2 is
    # what contains them.  We assert the selection keeps an honest
    # majority beyond the 2f phase-2 trim.
    idx = np.asarray(select_indices(jnp.asarray(full), f, base="krum"))
    n_byz_selected = int(np.sum(idx >= n - f))
    assert n_byz_selected <= f


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_bulyan_identical_honest_returns_that_vector(seed):
    rng = np.random.default_rng(seed)
    f, d = 1, 8
    n = 4 * f + 3
    v = rng.normal(size=(d,)).astype(np.float32)
    honest = np.tile(v, (n - f, 1))
    byz = rng.normal(scale=100.0, size=(f, d)).astype(np.float32)
    full = jnp.asarray(np.concatenate([honest, byz]))
    out = np.asarray(get_gar("bulyan-krum")(full, f).gradient)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)
