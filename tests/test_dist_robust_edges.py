"""Edge cases of the distributed robust-aggregation path that the main
semantics tests (test_dist.py) don't cover: quorum violations, the f=0
degenerate, single-leaf trees, mixed/bf16 dtypes, and the coordinate-phase
window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pytree as pt
from repro.dist.robust import (coordinate_phase_nd, distributed_aggregate,
                               inject_byzantine, pairwise_sq_dists_tree)

KEY = jax.random.PRNGKey(11)


def _tree(n, dtype=jnp.float32, key=KEY):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (n, 6, 4)).astype(dtype),
            "b": jax.random.normal(k2, (n, 5)).astype(dtype)}


class TestQuorum:
    def test_bulyan_quorum_raises(self):
        # f=1 needs n >= 4f+3 = 7
        with pytest.raises(ValueError, match="n >= 7"):
            distributed_aggregate(_tree(6), 1, "bulyan-krum")

    def test_krum_quorum_raises(self):
        # f=1 needs n >= 2f+3 = 5
        with pytest.raises(ValueError, match="n >= 5"):
            distributed_aggregate(_tree(4), 1, "krum")

    def test_unknown_gar_raises(self):
        with pytest.raises(KeyError, match="unknown GAR"):
            distributed_aggregate(_tree(7), 1, "no-such-rule")

    def test_non_distance_bulyan_base_rejected_early(self):
        # flat bulyan supports average/brute bases; the distributed
        # phase 1 works from distances alone and must say so up front
        with pytest.raises(KeyError, match="distance-only"):
            distributed_aggregate(_tree(7), 1, "bulyan-brute")

    def test_quorum_satisfied_at_boundary(self):
        agg, _ = distributed_aggregate(_tree(7), 1, "bulyan-krum")
        assert all(bool(jnp.all(jnp.isfinite(l)))
                   for l in jax.tree_util.tree_leaves(agg))


class TestDegenerateF0:
    def test_bulyan_f0_is_plain_mean(self):
        """f=0: theta=n, beta=theta, so selection keeps everyone and the
        coordinate phase averages all values — plain mean."""
        tree = _tree(5)
        agg, _ = distributed_aggregate(tree, 0, "bulyan-krum")
        want = jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), tree)
        for a, w in zip(jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(a, w, rtol=1e-5, atol=1e-6)

    def test_trimmed_mean_f0_is_average(self):
        tree = _tree(5)
        a0, _ = distributed_aggregate(tree, 0, "trimmed_mean")
        av, _ = distributed_aggregate(tree, 0, "average")
        for a, w in zip(jax.tree_util.tree_leaves(a0),
                        jax.tree_util.tree_leaves(av)):
            np.testing.assert_allclose(a, w, rtol=1e-5, atol=1e-6)

    def test_inject_f0_is_identity(self):
        tree = _tree(5)
        out = inject_byzantine(tree, 0, "signflip")
        for a, o in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(a, o)


class TestSingleLeaf:
    @pytest.mark.parametrize("gar", ["krum", "geomed", "bulyan-krum",
                                     "cwmed"])
    def test_single_leaf_matches_flat(self, gar):
        n, f = 11, 2
        tree = {"only": jax.random.normal(KEY, (n, 33))}
        agg, _ = distributed_aggregate(tree, f, gar)
        flat, ctx = pt.stack_flatten(tree)
        from repro.core import get_gar
        want = pt.unflatten(get_gar(gar)(flat, f).gradient, ctx)
        np.testing.assert_allclose(agg["only"], want["only"],
                                   rtol=1e-4, atol=1e-5)

    def test_vector_leaf_dists(self):
        """Leaves with no trailing dims (one scalar per worker) hit the
        empty-axes tensordot (outer-product Gram)."""
        n = 7
        tree = {"s": jax.random.normal(KEY, (n,)),
                "m": jax.random.normal(jax.random.fold_in(KEY, 1), (n, 3))}
        flat, _ = pt.stack_flatten(tree)
        from repro.core import pairwise_sq_dists
        np.testing.assert_allclose(pairwise_sq_dists_tree(tree),
                                   pairwise_sq_dists(flat),
                                   rtol=1e-4, atol=1e-4)


class TestDtypes:
    @pytest.mark.parametrize("gar", ["krum", "cwmed", "bulyan-krum"])
    def test_bf16_grads_aggregated_in_fp32(self, gar):
        """bf16 leaves: accumulation runs fp32 (matching stack_flatten's
        cast in the flat reference) and the output returns in bf16."""
        n, f = 11, 2
        tree = _tree(n, dtype=jnp.bfloat16)
        agg, _ = distributed_aggregate(tree, f, gar)
        for leaf in jax.tree_util.tree_leaves(agg):
            assert leaf.dtype == jnp.bfloat16
        want, _ = pt.aggregate_pytree(tree, gar, f)
        for a, w in zip(jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(a.astype(jnp.float32),
                                       w.astype(jnp.float32),
                                       rtol=2e-2, atol=2e-2)

    def test_mixed_dtype_tree(self):
        n, f = 9, 1
        tree = {"hi": jax.random.normal(KEY, (n, 8)),
                "lo": jax.random.normal(jax.random.fold_in(KEY, 2), (n, 8)
                                        ).astype(jnp.bfloat16)}
        agg, _ = distributed_aggregate(tree, f, "trimmed_mean")
        assert agg["hi"].dtype == jnp.float32
        assert agg["lo"].dtype == jnp.bfloat16

    def test_distance_matrix_fp32_from_bf16(self):
        tree = _tree(7, dtype=jnp.bfloat16)
        d2 = pairwise_sq_dists_tree(tree)
        assert d2.dtype == jnp.float32


class TestInjectParity:
    """The dist attacks must agree with the flat reference's conventions
    (core.attacks): global coordinate indexing, verbatim explicit gamma,
    and the flat defaults."""

    def test_lp_poisons_coordinate_in_later_leaf(self):
        n, f = 9, 2
        tree = {"a": jax.random.normal(KEY, (n, 4)),
                "b": jax.random.normal(jax.random.fold_in(KEY, 3), (n, 6))}
        # coord 7 lands in leaf "b" at local index 3
        out = inject_byzantine(tree, f, "omniscient_lp", coord=7,
                               gamma=5.0)
        mean_a = np.mean(np.asarray(tree["a"][:n - f]), axis=0)
        mean_b = np.mean(np.asarray(tree["b"][:n - f]), axis=0)
        np.testing.assert_allclose(out["a"][-1], mean_a, rtol=1e-5,
                                   atol=1e-6)
        want_b = mean_b.copy()
        want_b[3] += 5.0
        np.testing.assert_allclose(out["b"][-1], want_b, rtol=1e-5,
                                   atol=1e-6)

    def test_lp_explicit_gamma_ignores_margin(self):
        n, f = 9, 2
        tree = {"a": jax.random.normal(KEY, (n, 4))}
        out = inject_byzantine(tree, f, "omniscient_lp", coord=1,
                               gamma=3.0, margin=0.5)
        mean = np.mean(np.asarray(tree["a"][:n - f]), axis=0)
        np.testing.assert_allclose(float(out["a"][-1, 1] - mean[1]), 3.0,
                                   rtol=1e-5)

    def test_lp_coord_out_of_range_raises(self):
        tree = {"a": jax.random.normal(KEY, (9, 4))}
        with pytest.raises(ValueError, match="coord"):
            inject_byzantine(tree, 2, "omniscient_lp", coord=99)

    def test_lp_top_attacks_largest_mean_coordinate(self):
        n, f = 9, 2
        tree = {"a": jnp.ones((n, 3)) * 0.1,
                "b": jnp.ones((n, 4)).at[:, 2].set(50.0)}
        out = inject_byzantine(tree, f, "omniscient_lp", coord="top",
                               gamma=7.0)
        # largest-|mean| coordinate is b[2] (=50), attacked against its
        # sign: 50 - 7
        np.testing.assert_allclose(float(out["b"][-1, 2]), 43.0, rtol=1e-5)
        np.testing.assert_allclose(out["a"][-1],
                                   np.full((3,), 0.1, np.float32),
                                   rtol=1e-5)

    @pytest.mark.parametrize("attack", ["omniscient_lp", "omniscient_linf"])
    def test_gamma_closed_accepted(self, attack):
        """The flat API's gamma="closed" spelling must work (it is the
        only estimate the dist path has, so it aliases gamma=None)."""
        n, f = 9, 2
        tree = _tree(n)
        a = inject_byzantine(tree, f, attack, gamma="closed")
        b = inject_byzantine(tree, f, attack)
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(x, y)

    def test_random_default_scale_matches_core(self):
        n, f = 20, 10
        tree = {"a": jnp.zeros((n, 2000))}
        out = inject_byzantine(tree, f, "random",
                               key=jax.random.PRNGKey(7))
        sd = float(np.std(np.asarray(out["a"][-f:])))
        assert 8.0 < sd < 12.0  # core.random_noise default scale=10.0


class TestCoordinatePhaseWindow:
    def test_windowed_matches_unwindowed(self):
        sel = jax.random.normal(KEY, (9, 7, 13))  # 91 coords
        full = coordinate_phase_nd(sel, 2)
        for window in (1, 8, 64, 91, 1000):
            win = coordinate_phase_nd(sel, 2, window=window)
            np.testing.assert_allclose(win, full, rtol=1e-6, atol=1e-7)

    def test_beta_lt_one_raises(self):
        sel = jax.random.normal(KEY, (4, 5))
        with pytest.raises(ValueError, match="beta"):
            coordinate_phase_nd(sel, 2)  # beta = 4 - 4 = 0

    def test_windowed_in_aggregate(self):
        n, f = 11, 2
        tree = _tree(n)
        a_full, _ = distributed_aggregate(tree, f, "bulyan-geomed")
        a_win, _ = distributed_aggregate(tree, f, "bulyan-geomed", window=7)
        for a, w in zip(jax.tree_util.tree_leaves(a_win),
                        jax.tree_util.tree_leaves(a_full)):
            np.testing.assert_allclose(a, w, rtol=1e-6, atol=1e-7)
