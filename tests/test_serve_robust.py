"""Byzantine-resilient ensemble serving (repro.dist.serve_robust).

Pins the three contracts of the serving aggregation layer:

  1. semantics — aggregating an ``(n, B, V)`` logits stack equals the
     flat core rule on ``logits.reshape(n, -1)`` (no serving forks);
  2. robustness end-to-end — a poisoned replica flips greedy decode
     under ``average`` and is rejected by Krum/Bulyan through the full
     ``ServingEngine`` ensemble path;
  3. state — stateful rules thread one ``AggState`` across decode steps
     (dense-path parity and engine-carried threading).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import AggSpec, init_state, resolve_rule, rule_names
from repro.configs import get_reduced
from repro.core import get_gar
from repro.dist.serve_robust import (aggregate_logits, init_ensemble_state,
                                     make_robust_serve_step,
                                     poison_replicas, replicate_cache,
                                     replicate_params, stack_replicas)
from repro.models import init_cache, init_model
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# 1. parity with the flat core on stacked logits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gar", ["average", "cwmed", "trimmed_mean", "krum",
                                 "geomed", "multikrum", "centered_clip",
                                 "bulyan-krum", "bulyan-geomed"])
def test_aggregate_logits_matches_flat_core(gar):
    n, B, V, f = 11, 3, 32, 2
    logits = jax.random.normal(KEY, (n, B, V))
    agg, res = aggregate_logits(logits, f, gar)
    flat = get_gar(gar)(logits.reshape(n, -1), f).gradient.reshape(B, V)
    np.testing.assert_allclose(agg, flat, rtol=1e-5, atol=1e-5)
    assert agg.shape == (B, V)
    assert res.selected.shape == (n,)


def test_every_tree_rule_serves():
    """Acceptance pin: every registry rule with a tree implementation
    works unchanged as a serving aggregator (incl. composites and the
    stateful family)."""
    B, V, f = 2, 16, 1
    names = [n for n in rule_names()
             if resolve_rule(n).tree_fn is not None]
    names += ["bulyan-krum", "bulyan-geomed", "buffered-cwmed",
              "buffered-krum", "buffered-bulyan-krum",
              "reputation-krum", "reputation-buffered-cwmed",
              "reputation-bulyan-krum"]
    assert "krum" in names and "centered_clip_momentum" in names
    for i, name in enumerate(names):
        rule = resolve_rule(name)
        n = max(rule.min_n(f), 4)
        logits = jax.random.normal(jax.random.fold_in(KEY, i), (n, B, V))
        if rule.stateful:
            state = init_ensemble_state(AggSpec(f=f, gar=name), n, B, V)
            agg, res, state = aggregate_logits(logits, f, name, state=state)
            assert int(state.step) == 1
        else:
            agg, res = aggregate_logits(logits, f, name)
        assert agg.shape == (B, V), name
        assert bool(jnp.all(jnp.isfinite(agg))), name


def test_stack_replicas_matches_replicate():
    cfg = get_reduced("gemma_2b")
    params = init_model(KEY, cfg)
    stacked = stack_replicas([params, params, params])
    bcast = replicate_params(params, 3)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(a, b), stacked, bcast)
    jit = replicate_params(params, 3, jitter=1e-3, key=KEY)
    leaf = jax.tree_util.tree_leaves(jit)[0]
    assert leaf.shape[0] == 3
    assert not np.allclose(leaf[0], leaf[1])


# ---------------------------------------------------------------------------
# 2. engine end-to-end
# ---------------------------------------------------------------------------

def _serve(stacked, cfg, gar, f, prompt, tokens=6, **ekw):
    eng = ServingEngine(stacked, cfg, n_slots=1, cache_len=32,
                        ensemble=AggSpec(f=f, gar=gar, **ekw))
    return eng.run([Request(rid=0, prompt=prompt, max_new_tokens=tokens)],
                   max_steps=20)[0]


def test_ensemble_of_identical_replicas_matches_plain_engine():
    cfg = get_reduced("llama3_2_3b")
    params = init_model(KEY, cfg)
    prompt = np.asarray([1, 2, 3, 4, 5], np.int32)
    plain = ServingEngine(params, cfg, n_slots=1, cache_len=32)
    want = plain.run([Request(rid=0, prompt=prompt, max_new_tokens=5)],
                     max_steps=20)[0]
    stacked = replicate_params(params, 4)  # power of two: exact mean
    got = _serve(stacked, cfg, "average", 0, prompt, tokens=5)
    assert got == want


def test_poisoned_replica_rejected_end_to_end():
    """Poisoned replica flips greedy argmax under average, is rejected
    by krum and bulyan (matching the attack-free run token for token)."""
    cfg = get_reduced("llama3_2_3b")
    params = init_model(KEY, cfg)
    prompt = np.asarray([1, 2, 3, 4, 5], np.int32)
    n, f = 7, 1
    honest = replicate_params(params, n, jitter=1e-3,
                              key=jax.random.PRNGKey(7))
    poisoned = poison_replicas(honest, f, "signflip", scale=10.0)
    for gar in ("krum", "bulyan-krum", "reputation-krum"):
        clean = _serve(honest, cfg, gar, f, prompt)
        attacked = _serve(poisoned, cfg, gar, f, prompt)
        assert attacked == clean, gar
    clean_avg = _serve(honest, cfg, "average", f, prompt)
    attacked_avg = _serve(poisoned, cfg, "average", f, prompt)
    assert attacked_avg != clean_avg


def test_decode_time_logits_attack_rejected():
    """The in-graph omniscient adversary on the logits stack (spec.attack,
    mirroring make_train_step) steers average but not bulyan."""
    cfg = get_reduced("llama3_2_3b")
    params = init_model(KEY, cfg)
    prompt = np.asarray([2, 4, 6], np.int32)
    n, f = 7, 1
    stacked = replicate_params(params, n, jitter=1e-3,
                               key=jax.random.PRNGKey(3))
    akw = (("scale", 20.0),)
    clean = _serve(stacked, cfg, "bulyan-krum", f, prompt)
    att_bul = _serve(stacked, cfg, "bulyan-krum", f, prompt,
                     attack="signflip", attack_kwargs=akw)
    att_avg = _serve(stacked, cfg, "average", f, prompt,
                     attack="signflip", attack_kwargs=akw)
    assert att_bul == clean
    assert att_avg != clean


# ---------------------------------------------------------------------------
# 3. stateful rules across the decode stream
# ---------------------------------------------------------------------------

def test_stateful_dense_tree_parity_across_steps():
    """Threading AggState through aggregate_logits equals threading the
    dense rule over the same flat stacks, step for step."""
    n, B, V, f, W = 5, 2, 16, 1, 3
    rule = resolve_rule("buffered-cwmed", history_window=W)
    spec = AggSpec(f=f, gar="buffered-cwmed", history_window=W)
    t_state = init_ensemble_state(spec, n, B, V)
    d_state = init_state(rule, jnp.zeros((n, B * V)), flat=True)
    for step in range(4):
        logits = jax.random.normal(jax.random.fold_in(KEY, step), (n, B, V))
        agg, _, t_state = aggregate_logits(logits, f, "buffered-cwmed",
                                           state=t_state, history_window=W)
        d_res, d_state = rule.dense_fn(logits.reshape(n, -1), f, d_state)
        np.testing.assert_allclose(agg, d_res.gradient.reshape(B, V),
                                   rtol=1e-5, atol=1e-5)
    assert int(t_state.step) == 4


def test_engine_threads_agg_state_across_steps():
    cfg = get_reduced("gemma_2b")
    params = init_model(KEY, cfg)
    stacked = replicate_params(params, 5, jitter=1e-3, key=KEY)
    spec = AggSpec(f=1, gar="buffered-cwmed", history_window=3)
    eng = ServingEngine(stacked, cfg, n_slots=1, cache_len=32,
                        ensemble=spec)
    assert eng.agg_state is not None and int(eng.agg_state.step) == 0
    eng.admit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                      max_new_tokens=10))
    for _ in range(3):
        eng.step()
    assert int(eng.agg_state.step) == 3
    # the ring buffer actually absorbed the decode stream
    hist = eng.agg_state.history[0]
    assert hist.shape[:2] == (3, 5)
    assert bool(jnp.any(hist != 0))


def test_robust_serve_step_carries_cache_and_state():
    """Direct step-builder use (the dryrun path): three chained calls."""
    cfg = get_reduced("gemma_2b")
    params = init_model(KEY, cfg)
    n = 5
    stacked = replicate_params(params, n, jitter=1e-3, key=KEY)
    cache = replicate_cache(init_cache(cfg, batch=2, cache_len=16), n)
    spec = AggSpec(f=1, gar="centered_clip_momentum")
    step = jax.jit(make_robust_serve_step(cfg, spec))
    state = init_ensemble_state(spec, n, 2, cfg.vocab_size)
    token = jnp.asarray([[1], [2]], jnp.int32)
    for i in range(3):
        pos = jnp.full((2,), i, jnp.int32)
        logits, cache, res, state = step(stacked, cache, token, pos, state)
        assert logits.shape == (2, cfg.vocab_size)
    assert int(state.step) == 3


# ---------------------------------------------------------------------------
# satellites: dtype contract
# ---------------------------------------------------------------------------

def test_engine_positions_are_int32():
    """Host-side counters must be int32 (the dtype the jit'd step takes) —
    no int64 promotion at the host/device boundary."""
    cfg = get_reduced("gemma_2b")
    params = init_model(KEY, cfg)
    eng = ServingEngine(params, cfg, n_slots=2, cache_len=32)
    assert eng.positions.dtype == np.int32
    eng.admit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                      max_new_tokens=3))
    eng.step()
    assert eng.positions.dtype == np.int32
    assert eng.last_token.dtype == np.int32
