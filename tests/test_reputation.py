"""Contract battery for reputation-weighted aggregation (``repro.agg.
reputation``), the arbitrary-f family ``reputation-<base>``.

Pins the subsystem's load-bearing promises:

* **uniform reputation reproduces the base rule bitwise** — dense and
  tree paths, stateless and stateful bases, including the nested
  ``stale-`` / ``buffered-`` / ``fused-`` composites;
* the quorum is **constant in f** (``min_n(f) == base.min_n(0)``), so
  ``reputation-<base>`` runs in the f >= n/2 regime where the quorum
  family's canonical refusal fires;
* reputation **monotonically burns down** under the build-then-burn
  attack, and auxiliary-batch scoring defeats the anti-aligned colluding
  majority that drags ``average`` and fools under-declared ``krum``;
* the carried scores round-trip through the checkpoint store bitwise
  and compose with ``jax.eval_shape``;
* (hypothesis, when installed) weights live in [0, 1] with max exactly
  1, are invariant to rescaling the raw scores, and the score update is
  permutation-equivariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import (check_quorum, init_state, resolve_rule,
                       reputation_scale, reputation_scores,
                       step_size_multiplier, tree_reputation_scores,
                       update_reputation)
from repro.agg.state import AggState
from repro.core import attacks
from repro.dist.robust import distributed_aggregate

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp
    _HAS_HYPOTHESIS = True
except ImportError:  # the battery below degrades to a visible skip
    _HAS_HYPOTHESIS = False

KEY = jax.random.PRNGKey(7)

# every registered base family: plain rules plus one of each composite
# prefix (the resolver nests reputation- around all of them)
BASES = ["average", "brute", "centered_clip", "centered_clip_momentum",
         "cwmed", "geomed", "krum", "multikrum", "trimmed_mean",
         "bulyan-krum", "buffered-cwmed", "stale-krum", "fused-krum"]


def _base_result(base, g, f):
    """Run the base rule as the identity tests' reference."""
    rule = resolve_rule(base)
    if rule.stateful:
        res, _ = rule.dense_fn(g, f, init_state(rule, g))
        return res
    return rule.dense_fn(g, f)


class TestUniformIdentity:
    """Fresh (all-ones) reputation must be invisible to the base rule."""

    @pytest.mark.parametrize("base", BASES)
    def test_dense_bitwise(self, base):
        f = 2
        n = resolve_rule(base).min_n(f) + 1
        g = jax.random.normal(KEY, (n, 24), jnp.float32)
        rule = resolve_rule(f"reputation-{base}")
        assert rule.stateful
        assert rule.state_fields[0] == "reputation"
        state = init_state(rule, g)
        res, new_state = rule.dense_fn(g, f, state)
        want = _base_result(base, g, f)
        assert np.array_equal(np.asarray(res.gradient),
                              np.asarray(want.gradient))
        if want.selected is not None:
            assert np.array_equal(np.asarray(res.selected),
                                  np.asarray(want.selected))
        assert int(new_state.step) == 1
        rep = np.asarray(new_state.reputation)
        assert rep.shape == (n,)
        assert rep.min() >= 0.0 and rep.max() <= 1.0

    @pytest.mark.parametrize("base", [b for b in BASES
                                      if resolve_rule(b).tree_fn is not None])
    def test_tree_bitwise(self, base):
        f = 2
        n = resolve_rule(base).min_n(f) + 1
        kw, kb = jax.random.split(KEY)
        tree = {"w": jax.random.normal(kw, (n, 4, 3), jnp.float32),
                "b": jax.random.normal(kb, (n, 5), jnp.float32)}
        out = distributed_aggregate(tree, f, f"reputation-{base}")
        agg, _, new_state = out  # reputation-* is always stateful
        ref = distributed_aggregate(tree, f, base)
        ref_agg = ref[0]
        for a, b in zip(jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(ref_agg)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        rep = np.asarray(new_state.reputation)
        assert rep.shape == (n,)
        assert rep.min() >= 0.0 and rep.max() <= 1.0

    def test_nested_identity_both_orders(self):
        # stale- around reputation- (and the reverse) still reproduces
        # the plain base at fresh state: zero staleness scales by 1,
        # uniform reputation blends by identity
        f, n = 2, 8
        tree = {"w": jax.random.normal(KEY, (n, 6), jnp.float32)}
        ref = distributed_aggregate(tree, f, "krum")[0]
        for name in ("reputation-stale-krum", "stale-reputation-krum"):
            agg, _, state = distributed_aggregate(tree, f, name)
            assert np.array_equal(np.asarray(agg["w"]),
                                  np.asarray(ref["w"]))
            rep = np.asarray(state.reputation)
            assert rep.min() >= 0.0 and rep.max() <= 1.0

    @pytest.mark.parametrize("base", BASES)
    def test_min_n_constant_in_f(self, base):
        rule = resolve_rule(f"reputation-{base}")
        base_rule = resolve_rule(base)
        assert rule.min_n(0) == rule.min_n(7) == base_rule.min_n(0)


class TestResolver:
    def test_reputation_cannot_nest_reputation(self):
        with pytest.raises(KeyError, match="nest"):
            resolve_rule("reputation-reputation-krum")

    def test_state_field_order_tracks_wrap_order(self):
        assert resolve_rule("reputation-stale-krum").state_fields == \
            ("reputation", "bus")
        assert resolve_rule("stale-reputation-krum").state_fields == \
            ("bus", "reputation")

    def test_composites_cache_on_schedule_params(self):
        assert resolve_rule("reputation-krum") is \
            resolve_rule("reputation-krum")
        assert resolve_rule("reputation-krum", rep_lr=0.25) is not \
            resolve_rule("reputation-krum")

    def test_unknown_gar_message_names_the_family(self):
        with pytest.raises(KeyError, match="reputation-<base>"):
            resolve_rule("no-such-rule")


class TestArbitraryF:
    """f >= n/2: quorum rules refuse canonically; reputation-* runs."""

    def test_quorum_family_refuses_canonically(self):
        n, f = 8, 4
        with pytest.raises(ValueError) as ei:
            check_quorum("krum", n, f)
        assert str(ei.value) == f"krum requires n >= 11 for f={f}, got n={n}"
        check_quorum("reputation-krum", n, f)  # must not raise

    def test_reputation_refusal_uses_the_same_message(self):
        with pytest.raises(ValueError) as ei:
            check_quorum("reputation-krum", 2, 6)
        assert str(ei.value) == "reputation-krum requires n >= 3 for " \
                                "f=6, got n=2"

    def test_colluding_majority_defeats_quorum_rules_not_reputation(self):
        # n = 2f: half the committee submits one identical anti-aligned
        # point a bounded distance off the honest mean
        n, f, d = 8, 4, 32
        honest = 1.0 + 0.3 * jax.random.normal(KEY, (n - f, d), jnp.float32)
        byz = attacks.colluding_majority(honest, f, eps=30.0,
                                         direction="anti")
        full = jnp.concatenate([honest, byz], axis=0)
        clean = jnp.mean(honest, axis=0)

        def dev(v):
            return float(jnp.linalg.norm(v - clean))

        # average is dragged by the cluster
        d_avg = dev(_base_result("average", full, 0).gradient)
        # krum with an under-declared f "satisfies" its quorum and picks
        # a colluder: the identical cluster is the tightest neighborhood
        res_k = _base_result("krum", full, 1)
        sel = np.asarray(res_k.selected)  # (n,) selection mask
        assert sel[n - f:].sum() >= 1.0 and sel[:n - f].sum() == 0.0
        d_krum = dev(res_k.gradient)
        # both deviate by several times the honest-mean noise (~0.9 here)
        assert min(d_avg, d_krum) > 3.0

        # reputation-krum at the TRUE f, scored against an auxiliary
        # clean gradient (the train steps' AggSpec(aux_batch=...) path:
        # override the rule's agreement update from the pre-step scores)
        rule = resolve_rule("reputation-krum")
        state = init_state(rule, full)
        for _ in range(8):
            rep_prev = state.reputation
            res, state = rule.dense_fn(full, f, state)
            state = state._replace(reputation=update_reputation(
                rep_prev, reputation_scores(full, clean)))
        rep = np.asarray(state.reputation)
        assert rep[n - f:].max() < 0.15   # colluders distrusted
        assert rep[:n - f].min() > 0.8    # honest workers keep trust
        assert dev(res.gradient) < 0.2 * min(d_avg, d_krum)


class TestBurnDecay:
    def test_reputation_burn_decays_monotonically(self):
        n, f, d, build = 9, 3, 16, 3
        rule = resolve_rule("reputation-cwmed")
        base = 1.0 + 0.2 * jax.random.normal(KEY, (n - f, d), jnp.float32)
        state = init_state(rule, jnp.zeros((n, d), jnp.float32))
        byz_rep = []
        for t in range(8):
            honest = base + 0.05 * jax.random.normal(
                jax.random.fold_in(KEY, t), base.shape, jnp.float32)
            byz = attacks.reputation_burn(honest, f, step=t, build=build)
            full = jnp.concatenate([honest, byz], axis=0)
            _, state = rule.dense_fn(full, f, state)
            byz_rep.append(float(np.asarray(state.reputation)[n - f:].mean()))
        # build phase: mean-echoing keeps the attacker fully trusted
        assert byz_rep[build - 1] > 0.8
        # burn phase: every step must strictly erode the score
        burn = byz_rep[build:]
        assert all(b < a for a, b in zip(burn, burn[1:]))
        assert burn[-1] < 0.15
        rep = np.asarray(state.reputation)
        assert rep[:n - f].min() > rep[n - f:].max()


class TestReputationMath:
    def test_scores_map_alignment_to_unit_interval(self):
        t = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
        orth = jnp.asarray([2.0, -1.0, 4.0, -3.0], jnp.float32)
        g = jnp.stack([t, -t, orth, jnp.zeros_like(t)])
        s = np.asarray(reputation_scores(g, t))
        np.testing.assert_allclose(s, [1.0, 0.0, 0.5, 0.5], atol=1e-6)

    def test_tree_scores_match_flat_concatenation(self):
        k1, k2 = jax.random.split(KEY)
        a = jax.random.normal(k1, (5, 3, 2), jnp.float32)
        b = jax.random.normal(k2, (5, 4), jnp.float32)
        ta, tb = jnp.mean(a, 0), jnp.mean(b, 0)
        tree = np.asarray(tree_reputation_scores([a, b], [ta, tb]))
        flat = np.asarray(reputation_scores(
            jnp.concatenate([a.reshape(5, -1), b], axis=1),
            jnp.concatenate([ta.ravel(), tb])))
        np.testing.assert_allclose(tree, flat, rtol=1e-6)

    def test_update_repairs_out_of_range_restores(self):
        rep = jnp.asarray([-0.5, 2.0, 0.5], jnp.float32)
        new = np.asarray(update_reputation(
            rep, jnp.asarray([0.5, 0.5, 0.5]), 0.0, 1.0))
        assert new.min() >= 0.0 and new.max() <= 1.0

    def test_uniform_trust_multiplies_step_by_exactly_one(self):
        state = AggState(step=jnp.zeros((), jnp.int32),
                         reputation=jnp.ones((6,), jnp.float32))
        assert float(step_size_multiplier(state)) == 1.0
        assert np.array_equal(np.asarray(reputation_scale(state)),
                              np.ones(6, np.float32))


class TestCheckpointAndTracing:
    def test_checkpoint_roundtrip_continues_bitwise(self, tmp_path):
        from repro.checkpoint.store import load_checkpoint, save_checkpoint
        rule = resolve_rule("reputation-krum")
        f, n = 2, 8
        g = jax.random.normal(KEY, (n, 12), jnp.float32)
        state = init_state(rule, g)
        for t in range(3):
            _, state = rule.dense_fn(g + 0.01 * t, f, state)
        path = str(tmp_path / "agg_state")
        save_checkpoint(path, state, step=3)
        loaded, step = load_checkpoint(path, init_state(rule, g))
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(loaded)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        r1, s1 = rule.dense_fn(g, f, state)
        r2, s2 = rule.dense_fn(g, f, loaded)
        assert np.array_equal(np.asarray(r1.gradient),
                              np.asarray(r2.gradient))
        assert np.array_equal(np.asarray(s1.reputation),
                              np.asarray(s2.reputation))

    def test_eval_shape_composability(self):
        rule = resolve_rule("reputation-krum")
        tmpl = jax.ShapeDtypeStruct((9, 16), jnp.float32)
        st0 = jax.eval_shape(lambda: init_state(rule, tmpl))
        assert st0.reputation.shape == (9,)
        assert st0.reputation.dtype == jnp.float32

        def step(g, s):
            res, s2 = rule.dense_fn(g, 4, s)
            return res.gradient, s2

        out, s2 = jax.eval_shape(step, tmpl,
                                 init_state(rule, jnp.zeros((9, 16))))
        assert out.shape == (16,)
        assert s2.reputation.shape == (9,)

    def test_dist_init_agg_state_under_eval_shape(self):
        from repro.dist.train import DistByzantineSpec, init_agg_state
        spec = DistByzantineSpec(f=2, n_workers=7, gar="reputation-krum")
        params = {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32),
                  "b": jax.ShapeDtypeStruct((3,), jnp.float32)}
        st0 = jax.eval_shape(lambda: init_agg_state(spec, params, 7))
        assert st0.reputation.shape == (7,)

    def test_jit_carry(self):
        rule = resolve_rule("reputation-krum")
        g = jax.random.normal(KEY, (8, 10), jnp.float32)
        state = init_state(rule, g)

        @jax.jit
        def step(grads, s):
            res, s2 = rule.dense_fn(grads, 2, s)
            return res.gradient, s2

        for _ in range(3):
            out, state = step(g, state)
        assert np.isfinite(np.asarray(out)).all()
        assert int(state.step) == 3


class TestTrainerIntegration:
    def test_flat_trainer_crushes_signflip_and_scales_steps(self):
        from repro.data import ByzantineBatcher
        from repro.models import simple
        from repro.optim import get_optimizer
        from repro.training import ByzantineSpec, ByzantineTrainer

        def loss(params, x, y):
            return simple.classification_loss(
                simple.mnist_mlp_forward(params, x), y, params)

        spec = ByzantineSpec(n_workers=9, f=2, gar="reputation-krum",
                             attack="signflip", rep_lr=0.5)
        tr = ByzantineTrainer(loss, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.05), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 32), 5)
        rep = np.asarray(tr.agg_state.reputation)
        assert rep.shape == (9,)
        # sign-flipped submissions anti-align with the aggregate, so the
        # agreement EMA pushes the Byzantine tail of the stack below the
        # honest workers
        assert rep[-2:].max() < rep[:-2].min()
        assert 0.0 < tr.history[-1]["step_scale"] <= 1.0


if _HAS_HYPOTHESIS:
    @st.composite
    def _stacks(draw):
        n = draw(st.integers(2, 6))
        d = draw(st.integers(1, 8))
        elems = st.floats(-100.0, 100.0, width=32)
        g = draw(hnp.arrays(np.float32, (n, d), elements=elems))
        t = draw(hnp.arrays(np.float32, (d,), elements=elems))
        perm = draw(st.permutations(list(range(n))))
        return g, t, np.asarray(perm)

    _reps = hnp.arrays(
        np.float32, hnp.array_shapes(min_dims=1, max_dims=1,
                                     min_side=2, max_side=8),
        elements=st.floats(0.0, 1.0, width=32),
    ).filter(lambda r: float(r.max()) > 1e-6)

    def _state_of(rep):
        return AggState(step=jnp.zeros((), jnp.int32),
                        reputation=jnp.asarray(rep))

    class TestPropertyBattery:
        @given(rep=_reps)
        @settings(max_examples=50, deadline=None)
        def test_weights_unit_interval_max_exactly_one(self, rep):
            w = np.asarray(reputation_scale(_state_of(rep)))
            assert w.min() >= 0.0 and w.max() <= 1.0
            assert w.max() == 1.0  # x / x is exactly 1.0 in fp

        @given(rep=_reps, c=st.floats(0.1, 10.0))
        @settings(max_examples=50, deadline=None)
        def test_weights_invariant_to_rescaling(self, rep, c):
            w1 = np.asarray(reputation_scale(_state_of(rep)))
            w2 = np.asarray(reputation_scale(_state_of(c * rep)))
            np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-6)

        @given(data=_stacks())
        @settings(max_examples=50, deadline=None)
        def test_scores_permutation_equivariant(self, data):
            g, t, perm = data
            sp = np.asarray(reputation_scores(jnp.asarray(g[perm]),
                                              jnp.asarray(t)))
            s = np.asarray(reputation_scores(jnp.asarray(g),
                                             jnp.asarray(t)))
            assert np.array_equal(sp, s[perm])  # row-independent: bitwise
            assert s.min() >= -1e-5 and s.max() <= 1.0 + 1e-5

        @given(rep=hnp.arrays(np.float32, (5,),
                              elements=st.floats(-10.0, 10.0, width=32)),
               scores=hnp.arrays(np.float32, (5,),
                                 elements=st.floats(0.0, 1.0, width=32)),
               lr=st.floats(0.0, 1.0), decay=st.floats(0.01, 1.0))
        @settings(max_examples=50, deadline=None)
        def test_update_always_lands_in_unit_interval(self, rep, scores,
                                                      lr, decay):
            new = np.asarray(update_reputation(jnp.asarray(rep),
                                               jnp.asarray(scores),
                                               lr, decay))
            assert new.min() >= 0.0 and new.max() <= 1.0
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_battery_requires_hypothesis():
        """Visible placeholder for the hypothesis-backed battery above."""
