"""Per-architecture smoke tests (deliverable f): reduced same-family
variants run one forward + one Byzantine train step on CPU, asserting
output shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.dist.train import DistByzantineSpec, make_train_step
from repro.models import forward, init_model
from repro.models.attention import attention_blockwise, attention_naive
from repro.models.ssm import ssd_chunked
from repro.optim import get_optimizer

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b=2, s=64):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    extra = None
    if cfg.arch_type == "audio":
        extra = jax.random.normal(KEY, (b, cfg.encoder_seq, cfg.d_model))
    elif cfg.arch_type == "vlm":
        extra = jax.random.normal(KEY, (b, cfg.vision_seq, cfg.d_model))
    return tokens, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_reduced(arch)
        params = init_model(KEY, cfg)
        tokens, extra = _inputs(cfg)
        logits, aux = forward(params, cfg, tokens, extra)
        assert logits.shape == (2, 64, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.isfinite(aux))

    def test_one_byzantine_train_step(self, arch):
        cfg = get_reduced(arch)
        params = init_model(KEY, cfg)
        n, f, b, s = 7, 1, 1, 32
        batch = {
            "tokens": jax.random.randint(KEY, (n, b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(KEY, (n, b, s), 0, cfg.vocab_size),
        }
        if cfg.arch_type == "audio":
            batch["extra"] = jax.random.normal(
                KEY, (n, b, cfg.encoder_seq, cfg.d_model))
        elif cfg.arch_type == "vlm":
            batch["extra"] = jax.random.normal(
                KEY, (n, b, cfg.vision_seq, cfg.d_model))
        opt = get_optimizer("sgd", 1e-2)
        spec = DistByzantineSpec(f=f, gar="bulyan-krum",
                                 attack="omniscient_linf")
        step = jax.jit(make_train_step(cfg, spec, opt))
        new_params, _, metrics = step(params, opt.init(params), batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        # params actually moved
        moved = any(
            float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b_.astype(jnp.float32)))) > 0
            for a, b_ in zip(jax.tree_util.tree_leaves(params),
                             jax.tree_util.tree_leaves(new_params)))
        assert moved


class TestFullConfigsAnalytic:
    """Full configs are exercised via the dry-run; here we sanity-check the
    analytic parameter counts against the assignment's scale labels."""

    def test_param_counts_in_expected_range(self):
        expect = {
            "mixtral-8x22b": (120e9, 160e9),
            "mamba2-130m": (0.08e9, 0.2e9),
            "jamba-1.5-large-398b": (300e9, 480e9),
            "gemma-2b": (1.5e9, 3.5e9),
            "whisper-medium": (0.6e9, 0.9e9),  # 769M (enc+dec)
            "llama3.2-3b": (2.2e9, 4.5e9),
            "qwen1.5-4b": (2.5e9, 5e9),
            "gemma3-1b": (0.7e9, 1.7e9),
            "llama4-scout-17b-a16e": (90e9, 120e9),
            "llama-3.2-vision-11b": (8e9, 13e9),
        }
        for name, (lo, hi) in expect.items():
            n = get_config(name).param_count()
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"

    def test_moe_active_less_than_total(self):
        for name in ("mixtral-8x22b", "llama4-scout-17b-a16e",
                     "jamba-1.5-large-398b"):
            cfg = get_config(name)
            assert cfg.active_param_count() < 0.55 * cfg.param_count()


class TestAttentionVariants:
    def test_blockwise_matches_naive_causal(self):
        q = jax.random.normal(KEY, (2, 256, 8, 32))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 2, 32))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 2, 32))
        a = attention_naive(q, k, v, kind="attn")
        b = attention_blockwise(q, k, v, kind="attn", block_q=64, block_k=64)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("kind,kw", [("swa", {"window": 96}),
                                         ("chunked", {"chunk": 128})])
    def test_blockwise_matches_naive_local(self, kind, kw):
        q = jax.random.normal(KEY, (1, 256, 4, 16))
        k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 256, 4, 16))
        v = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 256, 4, 16))
        a = attention_naive(q, k, v, kind=kind, **kw)
        b = attention_blockwise(q, k, v, kind=kind, block_q=64, block_k=64,
                                **kw)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


class TestSSD:
    def test_chunked_matches_sequential_recurrence(self):
        b, s, h, p, n = 2, 64, 3, 8, 16
        k = jax.random.PRNGKey(5)
        x = jax.random.normal(k, (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                               (b, s, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (h,)))
        B = jax.random.normal(jax.random.fold_in(k, 3), (b, s, n))
        C = jax.random.normal(jax.random.fold_in(k, 4), (b, s, n))
        y = ssd_chunked(x, dt, A, B, C, chunk=16)
        # sequential oracle
        H = jnp.zeros((b, h, n, p))
        ys = []
        for t in range(s):
            decay = jnp.exp(dt[:, t] * A[None, :])
            inc = jnp.einsum("bn,bhp->bhnp", B[:, t],
                             x[:, t] * dt[:, t][..., None])
            H = H * decay[:, :, None, None] + inc
            ys.append(jnp.einsum("bn,bhnp->bhp", C[:, t], H))
        want = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(y, want, rtol=1e-3, atol=1e-3)

    def test_chunk_size_invariance(self):
        b, s, h, p, n = 1, 48, 2, 4, 8
        k = jax.random.PRNGKey(6)
        x = jax.random.normal(k, (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(k, (b, s, h)))
        A = -jnp.ones((h,))
        B = jax.random.normal(k, (b, s, n))
        C = jax.random.normal(k, (b, s, n))
        y1 = ssd_chunked(x, dt, A, B, C, chunk=8)
        y2 = ssd_chunked(x, dt, A, B, C, chunk=48)
        y3 = ssd_chunked(x, dt, A, B, C, chunk=32)  # forces padding
        np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(y1, y3, rtol=1e-4, atol=1e-4)
