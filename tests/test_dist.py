"""Distributed-path tests.

Semantics: ``distributed_aggregate`` (per-leaf, tensordot distances,
windowed coordinate phase) must equal the flat core GARs on the same data.

Mesh execution: an 8-device host-platform subprocess runs the sharded
train step on a (4, 2) mesh and checks it against the single-device result
— the subprocess is required because jax pins the device count at first
init and the rest of the suite must see 1 CPU device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_gar
from repro.core import pytree as pt
from repro.dist.robust import (coordinate_phase_nd, distributed_aggregate,
                               inject_byzantine, pairwise_sq_dists_tree)

KEY = jax.random.PRNGKey(4)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _stacked_tree(n, key=KEY):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": {"w": jax.random.normal(k1, (n, 8, 16))},
            "b": jax.random.normal(k2, (n, 64)),
            "c": jax.random.normal(k3, (n, 2, 3, 4))}


class TestDistributedAggregateSemantics:
    def test_pairwise_dists_match_flat(self):
        tree = _stacked_tree(9)
        flat, _ = pt.stack_flatten(tree)
        from repro.core import pairwise_sq_dists
        np.testing.assert_allclose(pairwise_sq_dists_tree(tree),
                                   pairwise_sq_dists(flat),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("gar", ["average", "cwmed", "trimmed_mean",
                                     "krum", "geomed", "multikrum",
                                     "brute", "centered_clip",
                                     "bulyan-krum", "bulyan-geomed"])
    def test_matches_core_gar(self, gar):
        n, f = 11, 2
        tree = _stacked_tree(n)
        agg, _ = distributed_aggregate(tree, f, gar)
        flat, ctx = pt.stack_flatten(tree)
        want = pt.unflatten(get_gar(gar)(flat, f).gradient, ctx)
        for a, w in zip(jax.tree_util.tree_leaves(agg),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(a, w, rtol=1e-4, atol=1e-5)

    def test_coordinate_phase_nd_matches_flat(self):
        from repro.core import coordinate_phase
        sel = jax.random.normal(KEY, (9, 4, 5, 6))
        out = coordinate_phase_nd(sel, 2)
        want = coordinate_phase(sel.reshape(9, -1), 2).reshape(4, 5, 6)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)

    def test_inject_byzantine_replaces_last_f(self):
        n, f = 11, 3
        tree = _stacked_tree(n)
        out = inject_byzantine(tree, f, "signflip")
        # structure must be preserved exactly: same top-level names, same
        # per-leaf shapes and dtypes
        assert isinstance(out, dict) and set(out) == set(tree)
        for name in ("a", "b", "c"):
            for a, o in zip(jax.tree_util.tree_leaves(tree[name]),
                            jax.tree_util.tree_leaves(out[name])):
                assert a.shape == o.shape
                assert a.dtype == o.dtype
        la = jax.tree_util.tree_leaves(tree)
        lo = jax.tree_util.tree_leaves(out)
        for a, o in zip(la, lo):
            np.testing.assert_array_equal(a[:n - f], o[:n - f])
            mean = np.mean(np.asarray(a[:n - f]), axis=0)
            np.testing.assert_allclose(o[n - f], -mean, rtol=1e-4,
                                       atol=1e-5)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_reduced
    from repro.dist.sharding import param_shardings, batch_pspec
    from repro.dist.train import DistByzantineSpec, make_train_step
    from repro.models import init_model
    from repro.optim import get_optimizer

    assert jax.device_count() == 8
    cfg = get_reduced("llama3_2_3b")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    opt = get_optimizer("momentum", 1e-2)
    spec = DistByzantineSpec(f=0, gar="bulyan-krum", attack="none")
    # n=4 workers < 4f+3 for f>0; use f=0 quorum-free bulyan? bulyan needs
    # n>=3 for f=0; theta=n, beta=n -> plain trimmed behaviour.
    step = make_train_step(cfg, spec, opt)
    n, b, s = 4, 2, 32
    batch = {
        "tokens": jax.random.randint(key, (n, b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (n, b, s), 0, cfg.vocab_size),
    }
    # single-device reference
    ref_params, ref_state, ref_m = jax.jit(step)(params, opt.init(params),
                                                 batch)

    with mesh:
        psh = param_shardings(params, mesh)
        sp = jax.device_put(params, psh)
        so = jax.device_put(opt.init(params), param_shardings(
            opt.init(params), mesh))
        bsh = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, batch_pspec(
                x.shape, mesh, worker_axis=True))), batch)
        out_params, out_state, m = jax.jit(step)(sp, so, bsh)

    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(ref_params),
                             jax.tree_util.tree_leaves(out_params))]
    print(json.dumps({
        "max_diff": max(diffs),
        "loss_diff": abs(float(ref_m["loss"]) - float(m["loss"])),
        "devices": jax.device_count(),
    }))
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["max_diff"] < 5e-2   # fp reassociation across shardings
    assert out["loss_diff"] < 1e-3
