import os
import sys

# src layout without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here by design — tests see the real single CPU device.
# Multi-device behaviour is tested via subprocesses (test_dist.py) so the
# 512-device override never leaks into this process.
