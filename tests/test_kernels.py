"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, in interpret mode (CPU container; kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bulyan_select, coord_stats, pairwise_gram, ref
from repro.kernels.ops import bulyan_coordinate, pairwise_distances

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("n,d", [(5, 64), (7, 100), (9, 129), (16, 2048),
                                 (25, 333), (31, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_gram_sweep(n, d, dtype):
    g = (jax.random.normal(KEY, (n, d)) * 3.0).astype(dtype)
    out = pairwise_gram(g, block_d=512, interpret=True)
    want = ref.pairwise_gram_ref(g)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("theta,f", [(5, 0), (7, 1), (9, 2), (11, 2),
                                     (13, 3), (16, 3), (31, 7)])
@pytest.mark.parametrize("d", [100, 129, 1024])
def test_bulyan_select_sweep(theta, f, d):
    s = jax.random.normal(jax.random.fold_in(KEY, theta * d), (theta, d))
    out = bulyan_select(s, f, block_d=256, interpret=True)
    want = ref.bulyan_select_ref(s, f)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bulyan_select_dtypes(dtype):
    """bf16 quantization makes distance *ties* likely; when two
    beta-windows are equidistant from the median, any minimal-deviation
    window is a valid Bulyan output (the paper's arg min is a set).  The
    oracle check therefore accepts every tie-optimal window mean."""
    theta, f, d = 9, 2, 512
    beta = theta - 2 * f
    s = jax.random.normal(KEY, (theta, d)).astype(dtype)
    out = np.asarray(bulyan_select(s, f, interpret=True), np.float32)

    sv = np.sort(np.asarray(s, np.float32), axis=0)
    med = sv[(theta - 1) // 2]
    ok = np.zeros((d,), bool)
    best = np.full((d,), np.inf)
    means = []
    for w in range(theta - beta + 1):
        dev = np.abs(sv[w:w + beta] - med).sum(0)
        means.append(sv[w:w + beta].mean(0))
        best = np.minimum(best, dev)
    eps = 1e-5 if dtype == jnp.float32 else 1e-2
    for w in range(theta - beta + 1):
        dev = np.abs(sv[w:w + beta] - med).sum(0)
        tie_ok = dev <= best * (1 + eps) + eps
        close = np.abs(out - means[w]) <= 1e-2 + 1e-3 * np.abs(means[w])
        ok |= tie_ok & close
    assert ok.all(), f"{(~ok).sum()} coords not a tie-optimal window mean"


def test_block_size_invariance():
    s = jax.random.normal(KEY, (11, 1000))
    outs = [bulyan_select(s, 2, block_d=b, interpret=True)
            for b in (128, 256, 1024)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-6)


def test_ops_wrappers_dispatch():
    g = jax.random.normal(KEY, (9, 300))
    np.testing.assert_allclose(
        pairwise_distances(g, use_pallas=True, block_d=128),
        pairwise_distances(g, use_pallas=False), rtol=1e-4, atol=1e-4)
    s = jax.random.normal(KEY, (9, 300))
    np.testing.assert_allclose(
        bulyan_coordinate(s, 2, use_pallas=True, block_d=128),
        bulyan_coordinate(s, 2, use_pallas=False), rtol=1e-5, atol=1e-5)


def test_gram_padding_exact():
    """Zero-padding d must not change distances."""
    g = jax.random.normal(KEY, (6, 130))  # forces padding at block 128
    out = pairwise_gram(g, block_d=128, interpret=True)
    want = ref.pairwise_gram_ref(g)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,f,d", [(7, 1, 200), (9, 2, 1000), (16, 3, 513),
                                   (15, 0, 128)])
def test_coord_stats_sweep(n, f, d):
    g = jax.random.normal(jax.random.fold_in(KEY, n * d), (n, d)) * 2.0
    med, trim = coord_stats(g, f, block_d=256, interpret=True)
    rmed, rtrim = ref.coord_stats_ref(g, f)
    np.testing.assert_allclose(med, rmed, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(trim, rtrim, rtol=1e-5, atol=1e-6)
