"""Adversarial self-audit tests: the harness passes on the shipped tree,
catches intentionally broken rules, and the satellite bugfixes (explicit
distributed flag, staleness clamp, canonical quorum errors, bounded
staleness) stay fixed."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import AggSpec, check_quorum, init_state, resolve_rule
from repro.agg.registry import AggregatorRule
from repro.agg.staleness import stale_scale
from repro.audit import (AuditReport, SweepConfig, audit_roster, certify,
                         check_quorum_contract, check_rule_output,
                         effective_stack, measure_leeway, run_sweep)
from repro.audit.invariants import (check_convex, check_finite, check_hull,
                                    check_trimmed)
from repro.audit.leeway import slope
from repro.core.types import AggResult

KEY = jax.random.PRNGKey(3)

#: tiny grid: every rule family and contract section, minimal corners
TINY = SweepConfig(d=8, fs=(1,), extra_n=(0,),
                   attacks=("none", "omniscient_lp", "signflip",
                            "stale_replay"),
                   steps=2, taus=(0, 2), quorum_fs=(1, 2))


class TestSweep:
    def test_tiny_sweep_is_clean(self):
        report = run_sweep(TINY)
        assert report.cases > 300
        assert report.ok(), report.violations
        # every section actually ran
        assert set(report.sections) == {"invariants", "quorum",
                                        "identity", "arbitrary-f",
                                        "staleness", "fp32",
                                        "speculative"}

    def test_roster_covers_every_family(self):
        roster = audit_roster()
        from repro.agg import rule_names
        assert set(rule_names()) <= set(roster)
        for prefix in ("bulyan-", "buffered-", "stale-", "stale-exp-",
                       "reputation-"):
            assert any(r.startswith(prefix) for r in roster), prefix
        for name in roster:
            assert resolve_rule(name).dense_fn is not None, name


class TestInvariantCheckers:
    """The checkers must *fail* on doctored outputs — a harness that
    can't catch a violation certifies nothing."""

    def _stack(self, n=5, d=4):
        return np.asarray(jax.random.normal(KEY, (n, d)), np.float32)

    def test_finite_catches_nan(self):
        assert check_finite(jnp.asarray([1.0, jnp.nan]))
        assert not check_finite(jnp.asarray([1.0, 2.0]))

    def test_hull_catches_escape(self):
        stack = self._stack()
        inside = stack.mean(axis=0)
        outside = stack.max(axis=0) + 1.0
        assert not check_hull(jnp.asarray(inside), stack)
        assert check_hull(jnp.asarray(outside), stack)

    def test_trimmed_catches_extreme(self):
        stack = self._stack(n=7)
        med = np.median(stack, axis=0)
        assert not check_trimmed(jnp.asarray(med), stack, f=2)
        assert check_trimmed(jnp.asarray(stack.max(axis=0)), stack, f=2)

    def test_convex_catches_lying_certificate(self):
        stack = self._stack()
        w = np.zeros(5, np.float32)
        w[0] = 1.0
        # certificate says worker 0, gradient is worker 1
        assert check_convex(jnp.asarray(stack[1]), jnp.asarray(w), stack)
        assert not check_convex(jnp.asarray(stack[0]), jnp.asarray(w),
                                stack)
        # weights that don't sum to 1 / go negative
        assert check_convex(jnp.asarray(stack[0]),
                            jnp.asarray(2.0 * w), stack)
        w2 = np.full(5, 0.4, np.float32)
        w2[0] = -0.6
        assert check_convex(jnp.asarray(w2 @ stack), jnp.asarray(w2),
                            stack)

    def test_weakened_rule_fails_the_output_audit(self):
        """A doctored 'krum' whose certificate lies about the winner is
        exactly the regression the declared-invariant dispatch exists
        to catch."""
        krum = resolve_rule("krum")

        def lying(grads, f):
            res = krum.dense_fn(grads, f)
            # report the right winner, emit a shifted aggregate
            return AggResult(res.gradient + 10.0, res.selected,
                             res.scores)

        fake = dataclasses.replace(krum, name="lying-krum",
                                   dense_fn=lying)
        grads = jnp.asarray(self._stack(n=9, d=4))
        res = fake.dense_fn(grads, 2)
        eff = effective_stack(fake, grads, None)
        violations = check_rule_output(fake, res.gradient, res.selected,
                                       eff, 2)
        assert violations  # hull + convex both blow up

    def test_effective_stack_recomputes_stale_scale(self):
        rule = resolve_rule("stale-krum")
        grads = jnp.asarray(self._stack(n=9, d=4))
        state = init_state(rule, grads)
        state = state._replace(
            step=jnp.asarray(4, jnp.int32),
            bus=state.bus._replace(
                versions=jnp.asarray([4, 3, 2, 1, 4, 3, 2, 1, 4],
                                     jnp.int32)))
        eff = effective_stack(rule, grads, state)
        scale = np.asarray(stale_scale(state), np.float32)
        np.testing.assert_allclose(
            eff, np.asarray(grads) * scale[:, None], rtol=1e-6)


class TestQuorumContract:
    """Satellite: every composite family raises the single canonical
    ValueError below quorum and the canonical KeyError distributed."""

    FAMILIES = ["krum", "bulyan-krum", "buffered-cwmed", "buffered-krum",
                "buffered-bulyan-krum", "stale-krum", "stale-cwmed",
                "stale-bulyan-krum", "stale-exp-krum", "stale-exp-cwmed",
                "stale-buffered-cwmed"]

    @pytest.mark.parametrize("gar", FAMILIES)
    def test_canonical_value_error(self, gar):
        assert check_quorum_contract(gar, 2) == []
        need = resolve_rule(gar).min_n(2)
        with pytest.raises(ValueError) as e:
            check_quorum(gar, need - 1, 2)
        assert str(e.value) == (
            f"{gar} requires n >= {need} for f=2, got n={need - 1}")

    @pytest.mark.parametrize("gar", ["bulyan-brute", "stale-bulyan-brute",
                                     "buffered-bulyan-cwmed"])
    def test_distributed_keyerror_for_treeless_composites(self, gar):
        with pytest.raises(KeyError, match="distance-only base"):
            check_quorum(gar, 11, 2, distributed=True)
        check_quorum(gar, 11, 2)  # flat path: fine

    def test_contract_checker_spots_a_drifted_message(self):
        """check_quorum_contract itself must flag a rule whose min_n
        and quorum error disagree — simulate by probing a composite
        with the wrong window (same message, so this passes) and a
        plain bogus name (KeyError -> caught upstream)."""
        assert check_quorum_contract("buffered-cwmed", 1,
                                     history_window=2) == []
        with pytest.raises(KeyError, match="unknown GAR"):
            check_quorum_contract("no-such-rule", 1)


class TestStalenessClamp:
    """Satellite: checkpoint-restore can leave bus versions ahead of the
    carried step; staleness must clamp at 0, never amplify."""

    def _state(self, rule, n=9, d=4, step=0, versions=None):
        grads = jnp.zeros((n, d), jnp.float32)
        state = init_state(rule, grads)
        if versions is not None:
            state = state._replace(
                step=jnp.asarray(step, jnp.int32),
                bus=state.bus._replace(
                    versions=jnp.asarray(versions, jnp.int32)))
        return state

    def test_negative_staleness_clamps_to_fresh(self):
        rule = resolve_rule("stale-krum")
        # restored bus stamped ahead of a zeroed step counter
        state = self._state(rule, step=0, versions=[5] * 9)
        np.testing.assert_array_equal(np.asarray(stale_scale(state)),
                                      np.ones(9, np.float32))

    def test_never_amplifies_mixed_clock_skew(self):
        rule = resolve_rule("stale-krum")
        state = self._state(rule, step=3,
                            versions=[9, 3, 1, 0, 7, 3, 2, 5, 3])
        scale = np.asarray(stale_scale(state))
        assert (scale <= 1.0 + 1e-7).all()
        assert scale.max() == pytest.approx(1.0)

    def test_restore_path_output_equals_base(self):
        """A uniformly future-stamped bus (the restore corner) must be
        bitwise the base rule, inv and exp weights alike."""
        grads = jnp.asarray(
            np.asarray(jax.random.normal(KEY, (9, 6)), np.float32))
        for name, base in (("stale-krum", "krum"),
                           ("stale-exp-cwmed", "cwmed")):
            rule = resolve_rule(name)
            state = self._state(rule, step=0, versions=[4] * 9)
            got, _ = rule.dense_fn(grads, 2, state)
            want = resolve_rule(base).dense_fn(grads, 2)
            np.testing.assert_array_equal(np.asarray(got.gradient),
                                          np.asarray(want.gradient))


class TestStalenessBound:
    def test_staleness_excess_reads_overshoot(self):
        from repro.dist.async_train import (GradientBus, resolve_tau,
                                            staleness_excess)
        bus = GradientBus(grads=jnp.zeros((4, 2)),
                          versions=jnp.asarray([5, 3, 1, 6], jnp.int32),
                          arrival_step=jnp.zeros((4,), jnp.int32))
        tau = resolve_tau(2, 4)
        np.testing.assert_array_equal(
            np.asarray(staleness_excess(bus, 6, tau)), [0, 1, 3, 0])
        # a future-stamped (lying) version shows as no excess: the
        # master can only observe the stamp
        np.testing.assert_array_equal(
            np.asarray(staleness_excess(bus, 5, tau)), [0, 0, 2, 0])

    def test_async_step_emits_the_metric(self):
        from repro.dist.async_train import staleness_excess  # noqa: F401
        import inspect
        from repro.dist import async_train
        src = inspect.getsource(async_train.make_async_train_step)
        assert "staleness_excess" in src


class TestLeeway:
    # Proposition 2 is asymptotic — at d = 16 the honest sampling noise
    # still dominates Bulyan's margin, so the ladder starts at 64 with
    # the paper-shaped committee (n = 15 = 4f + 3)
    DIMS = (64, 256)

    @pytest.fixture(scope="class")
    def report(self):
        return measure_leeway(
            rules=("average", "krum", "bulyan-krum",
                   ("bulyan-weak", "bulyan-krum", 0)),
            dims=self.DIMS, n_h=12, f=3, seed=11)

    def test_margins_scale_like_the_paper(self, report):
        rules = report["rules"]
        # Krum-family leeway and the average's poisoning margin grow
        assert rules["krum"]["slope_abs"] > 0.3
        assert rules["average"]["slope_abs"] > 0.3
        # Bulyan's relative margin shrinks (Proposition 2)
        assert rules["bulyan-krum"]["slope_rel"] < -0.25
        assert report["gamma"]["krum"]["slope"] > 0.3

    def test_weakened_rule_fails_certification(self, report):
        violations = certify(
            report,
            expectations={"bulyan-weak": ("rel", None, -0.25)})
        assert any("bulyan-weak" in v for v in violations)

    def test_healthy_rules_certify(self, report):
        violations = certify(
            report,
            expectations={"krum": ("abs", 0.3, None),
                          "bulyan-krum": ("rel", None, -0.25)})
        assert violations == []

    def test_baseline_gate_catches_margin_regression(self, report):
        doctored = json.loads(json.dumps(report))  # deep copy
        doctored["rules"]["bulyan-krum"]["margin_abs"] = [
            m * 10.0 for m in
            doctored["rules"]["bulyan-krum"]["margin_abs"]]
        violations = certify(report, expectations={},
                             baseline=doctored)
        assert any("bulyan-krum" in v for v in violations)
        assert certify(report, expectations={}, baseline=report) == []

    def test_report_is_deterministic(self):
        a = measure_leeway(rules=("krum",), dims=(16, 64), n_h=8, f=2,
                           seed=9)
        b = measure_leeway(rules=("krum",), dims=(16, 64), n_h=8, f=2,
                           seed=9)
        assert a == b

    def test_slope_fits_loglog(self):
        dims = (4, 16, 64)
        assert slope(dims, [np.sqrt(d) for d in dims]) == \
            pytest.approx(0.5, abs=1e-6)
        assert slope(dims, [10.0 / np.sqrt(d) for d in dims]) == \
            pytest.approx(-0.5, abs=1e-6)


class TestFp32Probes:
    def test_gram_probe_tight_on_bf16(self):
        from repro.kernels.probes import gram_fp32_contract_error
        assert gram_fp32_contract_error(n=4, d=512, block_d=256) < 1e-4

    def test_coord_probe_tight_on_bf16(self):
        from repro.kernels.probes import coord_fp32_contract_error
        assert coord_fp32_contract_error(theta=7, f=1, d=512,
                                         block_d=256) < 1e-4

    def test_probe_detects_low_precision_accumulation(self):
        """The probe's oracle casts the same quantized values to fp32 —
        an (emulated) bf16 accumulator at d >> 1/eps_bf16 must show."""
        from repro.kernels.ref import pairwise_gram_ref
        g = (jax.random.normal(KEY, (4, 4096), jnp.float32)
             .astype(jnp.bfloat16))
        want = pairwise_gram_ref(g.astype(jnp.float32))
        # emulate a kernel that accumulates the squared-norm reduction
        # in bf16 instead of fp32 (the ref itself upcasts first)
        sq = jnp.sum(g * g, axis=-1).astype(jnp.float32)
        g32 = g.astype(jnp.float32)
        lowp = jnp.maximum(
            sq[:, None] + sq[None, :] - 2.0 * (g32 @ g32.T), 0.0
        ) * (1.0 - jnp.eye(4, dtype=jnp.float32))
        err = float(jnp.max(jnp.abs(lowp - want))) / float(
            jnp.max(jnp.abs(want)))
        assert err > 1e-4  # the contract tolerance would flag it


class TestSweepCatchesInjectedBugs:
    def test_report_aggregation(self):
        r = AuditReport()
        r.add("a", 3, [])
        r.add("a", 2, ["boom"])
        r.add("b", 1, [])
        assert r.cases == 6 and not r.ok()
        assert r.sections == {"a": (5, 1), "b": (1, 0)}

    def test_hull_violation_is_reported_not_raised(self):
        avg = resolve_rule("average")

        def escaped(grads, f):
            res = avg.dense_fn(grads, f)
            return AggResult(res.gradient + 100.0, res.selected,
                             res.scores)

        fake = dataclasses.replace(avg, name="escaped-average",
                                   dense_fn=escaped)
        grads = jnp.asarray(
            np.asarray(jax.random.normal(KEY, (5, 4)), np.float32))
        res = fake.dense_fn(grads, 1)
        violations = check_rule_output(
            fake, res.gradient, res.selected,
            effective_stack(fake, grads, None), 1)
        assert any("hull" in v for v in violations)
