"""Serving-path integration: prefill + decode must reproduce the full
forward logits, per architecture family; engine end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import decode_step, forward, init_cache, init_model, prefill
from repro.serving import Request, ServingEngine

KEY = jax.random.PRNGKey(0)

FAMILIES = ["llama3_2_3b", "mamba2_130m", "whisper_medium",
            "jamba_1_5_large", "mixtral_8x22b", "gemma3_1b",
            "llama4_scout", "llama3_2_vision", "gemma_2b", "qwen1_5_4b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_matches_forward(arch):
    # no-drop MoE capacity: capacity-based dispatch is batch-size dependent
    # by design; exact consistency requires drop-free routing
    cfg = dataclasses.replace(get_reduced(arch), capacity_factor=100.0)
    params = init_model(KEY, cfg)
    B, S0, steps = 2, 24, 3
    S = S0 + steps
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extra = None
    if cfg.arch_type == "audio":
        extra = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    elif cfg.arch_type == "vlm":
        extra = jax.random.normal(KEY, (B, cfg.vision_seq, cfg.d_model))

    full_logits, _ = forward(params, cfg, tokens, extra)
    pre_logits, cache = prefill(params, cfg, tokens[:, :S0], extra,
                                cache_len=S)
    np.testing.assert_allclose(pre_logits, full_logits[:, :S0],
                               rtol=1e-3, atol=1e-3)
    for t in range(steps):
        pos = S0 + t
        logits1, cache = decode_step(params, cfg, cache,
                                     tokens[:, pos:pos + 1], pos)
        np.testing.assert_allclose(logits1[:, 0], full_logits[:, pos],
                                   rtol=2e-3, atol=2e-3)


def test_cache_shapes_bounded_for_local_attention():
    cfg = get_reduced("mixtral_8x22b")  # swa window 64
    cache = init_cache(cfg, batch=2, cache_len=4096)
    k = cache["periods"]["s0"]["k"]
    assert k.shape[2] == cfg.window  # ring cache, not 4096


def test_serving_engine_batched_requests():
    cfg = get_reduced("llama3_2_3b")
    params = init_model(KEY, cfg)
    engine = ServingEngine(params, cfg, n_slots=3, cache_len=64)
    reqs = [Request(rid=i,
                    prompt=np.arange(5 + i) % cfg.vocab_size,
                    max_new_tokens=4 + i) for i in range(5)]
    results = engine.run(reqs, max_steps=60)
    assert set(results) == {0, 1, 2, 3, 4}
    for i, toks in results.items():
        assert len(toks) == 4 + i
        assert all(0 <= t < cfg.vocab_size for t in toks)


def test_engine_matches_stepwise_decode():
    """Engine output == hand-rolled prefill + greedy decode."""
    cfg = get_reduced("gemma_2b")
    params = init_model(KEY, cfg)
    prompt = np.asarray([1, 2, 3, 4, 5], np.int32)
    engine = ServingEngine(params, cfg, n_slots=1, cache_len=32)
    out = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=5)],
                     max_steps=20)[0]

    logits, cache = prefill(params, cfg, jnp.asarray(prompt)[None],
                            cache_len=32)
    cur = int(jnp.argmax(logits[0, -1]))
    want = [cur]
    pos = len(prompt)
    for _ in range(4):
        l1, cache = decode_step(params, cfg, cache,
                                jnp.asarray([[cur]], jnp.int32), pos)
        cur = int(jnp.argmax(l1[0, 0]))
        want.append(cur)
        pos += 1
    assert out == want


def test_engine_mixed_length_slots_are_position_correct():
    """Two slots with different prompt lengths must each match their own
    single-slot decode (per-slot positions, not a shared max)."""
    cfg = get_reduced("llama3_2_3b")
    params = init_model(KEY, cfg)
    prompts = [np.asarray([1, 2, 3], np.int32),
               np.asarray([4, 5, 6, 7, 8, 9, 10], np.int32)]

    # reference: each request served alone
    want = {}
    for rid, prompt in enumerate(prompts):
        eng = ServingEngine(params, cfg, n_slots=1, cache_len=32)
        want[rid] = eng.run([Request(rid=rid, prompt=prompt,
                                     max_new_tokens=5)], max_steps=20)[rid]

    # batched: both in flight simultaneously
    eng = ServingEngine(params, cfg, n_slots=2, cache_len=32)
    got = eng.run([Request(rid=0, prompt=prompts[0], max_new_tokens=5),
                   Request(rid=1, prompt=prompts[1], max_new_tokens=5)],
                  max_steps=20)
    assert got[0] == want[0]
    assert got[1] == want[1]
