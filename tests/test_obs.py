"""Aggregation forensics & telemetry (repro.obs).

Pins the observability layer's four contracts:

  1. **bitwise identity** — ``obs-<base>`` returns the base rule's
     result unchanged on both the dense and the tree path, for every
     rule family (telemetry never touches the data path);
  2. **carrier composability** — the ``MetricsBuffer`` ring pushes
     under jit, composes with ``jax.eval_shape``, survives a
     numpy checkpoint roundtrip, and drains in chronological order
     across wraparound;
  3. **no host traffic** — the compiled telemetry train step lowers
     without host callbacks;
  4. **detection** — the drained forensics reproduce the paper's
     attack signatures (selection-entropy collapse under the
     omniscient attack, Byzantine rows ranked most suspect under a
     defended one) and the shared metrics schema holds across paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import AggSpec, init_state, resolve_rule, rule_names
from repro.agg.fused import fused_name
from repro.dist.robust import distributed_aggregate
from repro.obs import (METRIC_SCHEMA, AggDiagnostics, MetricsBuffer,
                       drain, init_metrics_buffer, obs_name, push_record,
                       selection_collapsed, selection_entropy,
                       suspicion_scores)
from repro.obs.detect import margin_trajectory

KEY = jax.random.PRNGKey(7)

# one representative per rule family (base, bulyan-, buffered-, stale-,
# reputation-, fused- — obs- itself is the wrapper under test)
FAMILIES = sorted(set(rule_names()) | {
    "bulyan-krum", "buffered-cwmed", "stale-krum",
    "reputation-krum", "fused-krum"})


def _stack(name: str, f: int = 2, d: int = 48):
    rule = resolve_rule(name)
    n = max(rule.min_n(f), f + 3)
    return jax.random.normal(KEY, (n, d), jnp.float32), n


# ---------------------------------------------------------------------------
# 1. bitwise identity per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", FAMILIES)
def test_obs_dense_bitwise_identical(name):
    base = resolve_rule(name)
    obs = resolve_rule(obs_name(name))
    g, n = _stack(name)
    f = 2
    if base.stateful:
        bstate = init_state(base, g)
        ostate = init_state(obs, g)
        bres, bstate = base.dense_fn(g, f, bstate)
        ores, ostate = obs.dense_fn(g, f, ostate)
        # the base's own carried fields evolve identically
        for fld in base.state_fields:
            np.testing.assert_array_equal(
                np.concatenate([np.ravel(x) for x in
                                jax.tree_util.tree_leaves(
                                    getattr(bstate, fld))] or [np.zeros(0)]),
                np.concatenate([np.ravel(x) for x in
                                jax.tree_util.tree_leaves(
                                    getattr(ostate, fld))] or [np.zeros(0)]))
    else:
        bres = base.dense_fn(g, f)
        ores, ostate = obs.dense_fn(g, f, init_state(obs, g))
    np.testing.assert_array_equal(np.asarray(bres.gradient),
                                  np.asarray(ores.gradient))
    np.testing.assert_array_equal(np.asarray(bres.selected),
                                  np.asarray(ores.selected))
    np.testing.assert_array_equal(np.asarray(bres.scores),
                                  np.asarray(ores.scores))
    assert int(np.asarray(ostate.obs.cursor)) == 1


@pytest.mark.parametrize("name", [n for n in FAMILIES
                                  if resolve_rule(n).tree_fn is not None])
def test_obs_tree_bitwise_identical(name):
    f = 2
    rule = resolve_rule(name)
    n = max(rule.min_n(f), f + 3)
    k1, k2 = jax.random.split(KEY)
    tree = {"w": jax.random.normal(k1, (n, 6, 5)),
            "b": jax.random.normal(k2, (n, 7))}
    out_b = distributed_aggregate(tree, f, name)
    out_o = distributed_aggregate(tree, f, obs_name(name))
    for lb, lo in zip(jax.tree_util.tree_leaves(out_b[0]),
                      jax.tree_util.tree_leaves(out_o[0])):
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(lo))
    np.testing.assert_array_equal(np.asarray(out_b[1].selected),
                                  np.asarray(out_o[1].selected))


# ---------------------------------------------------------------------------
# 2. MetricsBuffer carrier semantics
# ---------------------------------------------------------------------------

def _record(step: int, n: int) -> AggDiagnostics:
    v = jnp.full((n,), float(step), jnp.float32)
    return AggDiagnostics(step=jnp.float32(step), selected=v, scores=v,
                          dist_to_agg=v, trimmed_frac=v, reputation=v,
                          staleness=v, agg_dev=jnp.float32(step),
                          spread=jnp.float32(step))


def test_ring_wraparound_drains_chronologically():
    buf = init_metrics_buffer(4, 3)

    @jax.jit
    def push(b, s):
        return push_record(b, _record(0, 3)._replace(
            step=s.astype(jnp.float32)))

    for s in range(6):
        buf = push(buf, jnp.int32(s))
    out = drain(buf)
    assert out["pushed"] == 6
    assert [int(r["step"]) for r in out["records"]] == [2, 3, 4, 5]
    assert out["selection_frequency"].shape == (3,)


def test_buffer_composes_with_eval_shape():
    def body():
        buf = init_metrics_buffer(8, 5)
        return push_record(buf, _record(1, 5))

    abstract = jax.eval_shape(body)
    assert isinstance(abstract, MetricsBuffer)
    assert abstract.records.selected.shape == (8, 5)
    assert abstract.cursor.shape == ()


def test_buffer_checkpoint_roundtrip():
    buf = init_metrics_buffer(4, 3)
    for s in range(3):
        buf = push_record(buf, _record(s, 3))
    # checkpoint: leaves to host numpy, restore via tree_unflatten
    leaves, treedef = jax.tree_util.tree_flatten(buf)
    saved = [np.asarray(x) for x in leaves]
    restored = jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(x) for x in saved])
    a, b = drain(buf), drain(restored)
    assert a["pushed"] == b["pushed"]
    for ra, rb in zip(a["records"], b["records"]):
        for k in ra:
            np.testing.assert_array_equal(ra[k], rb[k])
    # and the restored ring keeps recording
    more = drain(push_record(restored, _record(9, 3)))
    assert more["pushed"] == 4


def test_drain_of_empty_obs_is_empty():
    out = drain(())
    assert out["pushed"] == 0 and out["records"] == []


# ---------------------------------------------------------------------------
# registry & spec plumbing
# ---------------------------------------------------------------------------

def test_obs_wraps_outermost_and_rejects_nesting():
    assert resolve_rule("obs-krum").name == "obs-krum"
    assert resolve_rule("obs-stale-krum").stateful
    assert fused_name("obs-krum") == "obs-fused-krum"
    assert resolve_rule("obs-fused-krum").name == "obs-fused-krum"
    with pytest.raises(KeyError, match="cannot nest"):
        resolve_rule("obs-obs-krum")
    with pytest.raises(KeyError, match="unknown GAR"):
        resolve_rule("obs-nonsense")


def test_spec_telemetry_selects_effective_gar():
    assert AggSpec(f=2, gar="krum").effective_gar == "krum"
    assert AggSpec(f=2, gar="krum", telemetry=True).effective_gar \
        == "obs-krum"
    spec = AggSpec(f=2, gar="krum", telemetry=True)
    assert spec.rule().name == "obs-krum"
    # quorum contract is the base's own
    assert spec.rule().min_n(2) == resolve_rule("krum").min_n(2)


# ---------------------------------------------------------------------------
# 3. compiled step stays host-callback-free
# ---------------------------------------------------------------------------

def test_no_host_callbacks_in_compiled_telemetry_step():
    from repro.data import ByzantineBatcher
    from repro.models import simple
    from repro.optim import get_optimizer
    from repro.training import ByzantineSpec
    from repro.training.trainer import (init_flat_agg_state,
                                        make_byzantine_step)

    def loss_fn(params, x, y):
        return simple.classification_loss(
            simple.mnist_mlp_forward(params, x), y, params)

    spec = ByzantineSpec(n_workers=9, f=2, gar="krum", attack="signflip",
                         telemetry=True)
    opt = get_optimizer("sgd", 0.05)
    params = simple.init_mnist_mlp(KEY)
    x, y = ByzantineBatcher("mnist", spec.n_honest, 8).batch(0)
    step = make_byzantine_step(loss_fn, opt, spec, attack_on=True)
    txt = jax.jit(step).lower(
        params, opt.init(params), jnp.asarray(x), jnp.asarray(y), KEY,
        init_flat_agg_state(spec, params)).as_text()
    assert "callback" not in txt.lower()


# ---------------------------------------------------------------------------
# 4. detection regressions (the paper's attack, observed live)
# ---------------------------------------------------------------------------

def _run_trainer(gar, attack, n_workers, f, steps):
    from repro.data import ByzantineBatcher
    from repro.models import simple
    from repro.optim import get_optimizer
    from repro.training import ByzantineSpec, ByzantineTrainer

    def loss_fn(params, x, y):
        return simple.classification_loss(
            simple.mnist_mlp_forward(params, x), y, params)

    kw = (("gar_name", gar),) if attack == "omniscient_lp" else ()
    spec = ByzantineSpec(n_workers=n_workers, f=f, gar=gar, attack=attack,
                         attack_kwargs=kw, telemetry=True)
    tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                          get_optimizer("sgd", 0.05), spec, seed=3)
    tr.run(ByzantineBatcher("mnist", spec.n_honest, 16), steps)
    return tr


def test_telemetry_off_run_is_bitwise_identical():
    """The flip side of the obs contract at trainer level: a telemetry
    run updates params exactly like an uninstrumented one."""
    runs = {}
    for telemetry in (False, True):
        from repro.data import ByzantineBatcher
        from repro.models import simple
        from repro.optim import get_optimizer
        from repro.training import ByzantineSpec, ByzantineTrainer

        def loss_fn(params, x, y):
            return simple.classification_loss(
                simple.mnist_mlp_forward(params, x), y, params)

        spec = ByzantineSpec(n_workers=9, f=2, gar="krum",
                             attack="signflip", telemetry=telemetry)
        tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.05), spec, seed=3)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 16), 3)
        runs[telemetry] = tr
    for a, b in zip(jax.tree_util.tree_leaves(runs[False].params),
                    jax.tree_util.tree_leaves(runs[True].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ma, mb in zip(runs[False].history, runs[True].history):
        assert ma == mb
    assert runs[False].telemetry()["pushed"] == 0
    assert runs[True].telemetry()["pushed"] == 3


def test_suspicion_ranks_byzantine_rows_first():
    tr = _run_trainer("krum", "signflip", n_workers=9, f=2, steps=5)
    out = tr.telemetry()
    s = suspicion_scores(out["records"], out["selection_frequency"])
    assert s.shape == (9,)
    # the defended attack's rows (the appended tail) rank most suspect
    assert set(np.argsort(s)[-2:]) == {7, 8}


def test_selection_entropy_collapses_under_paper_attack():
    clean = _run_trainer("krum", "none", n_workers=9, f=0, steps=5)
    poisoned = _run_trainer("krum", "omniscient_lp", n_workers=9, f=2,
                            steps=5)
    h_clean = selection_entropy(clean.telemetry()["selection_frequency"])
    h_att = selection_entropy(poisoned.telemetry()["selection_frequency"])
    assert h_att < h_clean
    assert selection_collapsed(
        poisoned.telemetry()["selection_frequency"])
    # margins exist for every recorded step and stay plottable
    m = margin_trajectory(poisoned.telemetry()["records"])
    assert m.shape == (5,) and np.all(m >= -1.0)


# ---------------------------------------------------------------------------
# satellite: one metrics schema across execution paths
# ---------------------------------------------------------------------------

def test_metric_keys_consistent_across_flat_paths():
    from repro.data import ByzantineBatcher
    from repro.models import simple
    from repro.optim import get_optimizer
    from repro.training import (AsyncByzantineTrainer, ByzantineSpec,
                                ByzantineTrainer)

    def loss_fn(params, x, y):
        return simple.classification_loss(
            simple.mnist_mlp_forward(params, x), y, params)

    sync_spec = ByzantineSpec(n_workers=9, f=2, gar="krum",
                              attack="signflip")
    sync = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                            get_optimizer("sgd", 0.05), sync_spec)
    sync.run(ByzantineBatcher("mnist", sync_spec.n_honest, 8), 1)
    sync_keys = set(sync.history[0]) - {"step"}

    async_spec = ByzantineSpec(n_workers=9, f=2, gar="krum",
                               attack="signflip", async_tau=2)
    a = AsyncByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.05), async_spec)
    a.run(ByzantineBatcher("mnist", async_spec.n_honest, 8), 1)
    async_keys = set(a.history[0]) - {"step"}

    assert sync_keys <= set(METRIC_SCHEMA)
    assert async_keys <= set(METRIC_SCHEMA)
    # the async path emits exactly the sync keys plus the async extras —
    # the historic drift (staleness_excess missing on the flat async
    # path) cannot reappear
    extras = {k for k, (paths, _) in METRIC_SCHEMA.items()
              if paths == "async"}
    assert async_keys == sync_keys | extras
    assert "staleness_excess" in async_keys
