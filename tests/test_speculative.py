"""Robust speculative decoding + continuous batching.

Pins the contracts of the speculative serving stack:

  1. **multi-token verify** — ``verify_step`` over a ``(B, k)`` block
     matches ``k`` sequential ``decode_step`` calls to float-
     accumulation tolerance (the batched attention einsum may contract
     in a different order) with identical argmaxes, and
     ``verify_supported`` gates the architectures whose caches cannot
     roll back rejected drafts;
  2. **robust verify semantics** — ``make_robust_verify_step``'s
     per-position scan aggregation matches ``k`` calls of the per-token
     robust serve step (same tolerance; ``AggState`` of stateful rules
     threads identically);
  3. **k = 1 identity** — the speculative engine at ``speculative_k=1``
     reproduces the per-token engine stream bitwise for every registered
     tree rule (stateless speculation is additionally lossless at any
     ``k``);
  4. **Byzantine acceptance** — a poisoned (colluding) draft and ``f``
     poisoned verifiers at the ``n = 4f + 3`` quorum edge both leave the
     accepted stream equal to the clean-ensemble greedy stream;
  5. **continuous batching** — ``submit``/``step`` admit queued requests
     into freed slots mid-stream, and a reused slot never inherits the
     previous occupant's aggregation state;
  6. **fused backend** — ``distance_backend="fused"`` threads through
     the verify path and matches ``xla`` on ``(n, B*k, vocab)`` stacks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import AggSpec, resolve_rule, rule_names
from repro.configs import get_reduced
from repro.dist.serve_robust import (aggregate_logits, init_ensemble_state,
                                     make_robust_serve_step,
                                     make_robust_verify_step,
                                     poison_replicas, replicate_params,
                                     reset_slot_state)
from repro.models import (decode_step, init_model, prefill, verify_step,
                          verify_supported)
from repro.models.config import ModelConfig
from repro.serving import (Request, ServingEngine, accept_block,
                           draft_cache_view, make_draft_propose)

KEY = jax.random.PRNGKey(0)


def _micro_cfg() -> ModelConfig:
    """One-layer dense micro model: fast jit, real prefill/decode path."""
    return ModelConfig(
        name="spec-test", arch_type="dense",
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab_size=128, head_dim=32,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )


def _req(rid: int, seed: int, n_new: int, vocab: int,
         plen: int = 5) -> Request:
    rng = np.random.RandomState(seed)
    return Request(rid=rid,
                   prompt=rng.randint(0, vocab, size=(plen,)
                                      ).astype(np.int32),
                   max_new_tokens=n_new)


# ---------------------------------------------------------------------------
# 1. multi-token verify path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma_2b"])
def test_verify_step_matches_sequential_decode(arch):
    cfg = get_reduced(arch)
    ok, why = verify_supported(cfg)
    assert ok, why
    params = init_model(KEY, cfg)
    B, P, k, L = 2, 5, 4, 32
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    _, cache = prefill(params, cfg, toks, cache_len=L)
    cache_seq = jax.tree_util.tree_map(lambda x: x.copy(), cache)
    block = jax.random.randint(jax.random.PRNGKey(1), (B, k), 0,
                               cfg.vocab_size)
    pos = jnp.full((B,), P, jnp.int32)
    vlog, _ = verify_step(params, cfg, cache, block, pos)
    for j in range(k):
        lj, cache_seq = decode_step(params, cfg, cache_seq,
                                    block[:, j:j + 1], pos + j)
        np.testing.assert_allclose(np.asarray(vlog[:, j]),
                                   np.asarray(lj[:, 0]), atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(vlog[:, j], -1)),
            np.asarray(jnp.argmax(lj[:, 0], -1)))


def test_verify_supported_gates_ring_and_ssm_caches():
    # swa / chunked ring caches wrap rejected-draft garbage onto valid
    # entries; mamba's recurrent state cannot roll back at all
    swa = get_reduced("mixtral_8x22b")
    ok, why = verify_supported(swa)
    assert not ok and "swa" in why
    with pytest.raises(ValueError):
        make_robust_verify_step(swa, AggSpec(f=1, gar="krum"))


def test_verify_step_staggered_positions():
    # per-slot position vectors: slots verify at different depths
    cfg = _micro_cfg()
    params = init_model(KEY, cfg)
    B, k, L = 2, 3, 32
    toks = jax.random.randint(KEY, (B, 6), 0, cfg.vocab_size)
    _, cache = prefill(params, cfg, toks, cache_len=L)
    cache_seq = jax.tree_util.tree_map(lambda x: x.copy(), cache)
    block = jax.random.randint(jax.random.PRNGKey(1), (B, k), 0,
                               cfg.vocab_size)
    pos = jnp.asarray([6, 4], jnp.int32)  # slot 1 behind slot 0
    vlog, _ = verify_step(params, cfg, cache, block, pos)
    for j in range(k):
        lj, cache_seq = decode_step(params, cfg, cache_seq,
                                    block[:, j:j + 1], pos + j)
        np.testing.assert_allclose(np.asarray(vlog[:, j]),
                                   np.asarray(lj[:, 0]), atol=1e-5)


# ---------------------------------------------------------------------------
# 2. robust verify == k per-token robust steps (AggState included)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gar", ["krum", "cwmed", "bulyan-krum",
                                 "buffered-krum",
                                 "centered_clip_momentum",
                                 "reputation-krum",
                                 "reputation-buffered-krum"])
def test_robust_verify_scan_matches_per_position_aggregation(gar):
    # the verify step's lax.scan must aggregate per position in stream
    # order, threading the AggState exactly like k per-token
    # aggregations over the same logits stack would — bitwise
    cfg = _micro_cfg()
    n, f, B, P, k, L = 7, 1, 2, 5, 4, 32
    params = init_model(KEY, cfg)
    sp = replicate_params(params, n, jitter=1e-3,
                          key=jax.random.PRNGKey(7))
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    scache = jax.vmap(lambda p: prefill(p, cfg, toks, cache_len=L)[1])(sp)
    spec = AggSpec(f=f, gar=gar)
    verify = jax.jit(make_robust_verify_step(cfg, spec))
    state = init_ensemble_state(spec, n, B, cfg.vocab_size)
    block = jax.random.randint(jax.random.PRNGKey(1), (B, k), 0,
                               cfg.vocab_size)
    pos = jnp.full((B,), P, jnp.int32)
    agg_k, _, _, st_new = verify(
        sp, jax.tree_util.tree_map(lambda x: x.copy(), scache),
        block, pos, state)
    # reference: the identical model pass, then k sequential
    # aggregate_logits calls threading the state by hand
    stack, _ = jax.vmap(
        lambda p, c: verify_step(p, cfg, c, block, pos)
    )(sp, jax.tree_util.tree_map(lambda x: x.copy(), scache))
    stack = stack.astype(jnp.float32)
    stateful = spec.rule().stateful
    st_ref = state
    for j in range(k):
        out = aggregate_logits(stack[:, :, j, :], f, gar,
                               state=st_ref if stateful else None)
        if stateful:
            agg, _, st_ref = out
        else:
            agg, _ = out
        # jit+scan may fuse the trimmed-mean arithmetic differently
        # than the eager reference — selection itself is exact
        np.testing.assert_allclose(np.asarray(agg_k[:, j]),
                                   np.asarray(agg), atol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(agg_k[:, j], -1)),
            np.asarray(jnp.argmax(agg, -1)))
    if stateful:
        for a, b in zip(jax.tree_util.tree_leaves(st_new),
                        jax.tree_util.tree_leaves(st_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


# ---------------------------------------------------------------------------
# 3. engine k=1 identity (every registered tree rule) + lossless k>1
# ---------------------------------------------------------------------------

def _tree_rules():
    names = [r for r in rule_names()
             if resolve_rule(r).tree_fn is not None]
    return names + ["bulyan-krum", "buffered-krum", "fused-krum",
                    "reputation-krum"]


@pytest.mark.parametrize("gar", _tree_rules())
def test_engine_speculative_k1_bitwise_identity(gar):
    cfg = _micro_cfg()
    f = 1
    n = max(resolve_rule(gar).min_n(f), 3)
    params = init_model(KEY, cfg)
    sp = replicate_params(params, n, jitter=1e-3,
                          key=jax.random.PRNGKey(7))
    reqs = lambda: [_req(0, 0, 6, cfg.vocab_size),
                    _req(1, 1, 9, cfg.vocab_size)]
    base = AggSpec(f=f, gar=gar)
    ref = ServingEngine(sp, cfg, n_slots=2, cache_len=64,
                        ensemble=base).run(reqs(), 64)
    spec = dataclasses.replace(base, speculative_k=1)
    out = ServingEngine(sp, cfg, n_slots=2, cache_len=64,
                        ensemble=spec).run(reqs(), 64)
    assert out == ref


@pytest.mark.parametrize("gar", ["krum", "bulyan-krum", "cwmed"])
def test_engine_speculative_stateless_lossless_any_k(gar):
    # greedy speculation with an honest draft never changes a stateless
    # rule's stream — rejections only cost throughput
    cfg = _micro_cfg()
    params = init_model(KEY, cfg)
    sp = replicate_params(params, 7, jitter=1e-3,
                          key=jax.random.PRNGKey(7))
    reqs = lambda: [_req(0, 0, 8, cfg.vocab_size),
                    _req(1, 1, 12, cfg.vocab_size)]
    base = AggSpec(f=1, gar=gar)
    ref = ServingEngine(sp, cfg, n_slots=2, cache_len=64,
                        ensemble=base).run(reqs(), 64)
    for k in (2, 4):
        spec = dataclasses.replace(base, speculative_k=k)
        out = ServingEngine(sp, cfg, n_slots=2, cache_len=64,
                            ensemble=spec).run(reqs(), 64)
        assert out == ref, f"k={k} changed a stateless greedy stream"


# ---------------------------------------------------------------------------
# 4. Byzantine acceptance: poisoned draft / poisoned verifiers
# ---------------------------------------------------------------------------

def _clean_and_poisoned(cfg, n, f):
    params = init_model(KEY, cfg)
    honest = replicate_params(params, n, jitter=1e-3,
                              key=jax.random.PRNGKey(7))
    return honest, poison_replicas(honest, f, "signflip", scale=10.0)


def test_poisoned_draft_cannot_change_the_stream():
    # the drafting replica colludes (last replica poisoned, draft reads
    # it): every proposal dies at the aggregate, the emitted stream is
    # the clean ensemble's greedy stream
    cfg = _micro_cfg()
    n, f = 7, 1
    honest, poisoned = _clean_and_poisoned(cfg, n, f)
    reqs = lambda: [_req(0, 3, 12, cfg.vocab_size, plen=6)]
    clean = ServingEngine(honest, cfg, n_slots=1, cache_len=64,
                          ensemble=AggSpec(f=f, gar="bulyan-krum")
                          ).run(reqs(), 64)
    spec = AggSpec(f=f, gar="bulyan-krum", speculative_k=4,
                   draft_replica=n - 1)
    out = ServingEngine(poisoned, cfg, n_slots=1, cache_len=64,
                        ensemble=spec).run(reqs(), 64)
    assert out == clean


def test_poisoned_verifiers_at_quorum_edge():
    # n = 4f + 3 (bulyan quorum edge): f poisoned verifiers can neither
    # veto honest drafts nor force their own tokens
    cfg = _micro_cfg()
    n, f = 7, 1
    honest, poisoned = _clean_and_poisoned(cfg, n, f)
    reqs = lambda: [_req(0, 3, 12, cfg.vocab_size, plen=6)]
    clean = ServingEngine(honest, cfg, n_slots=1, cache_len=64,
                          ensemble=AggSpec(f=f, gar="bulyan-krum")
                          ).run(reqs(), 64)
    spec = AggSpec(f=f, gar="bulyan-krum", speculative_k=4,
                   draft_replica=0)
    out = ServingEngine(poisoned, cfg, n_slots=1, cache_len=64,
                        ensemble=spec).run(reqs(), 64)
    assert out == clean


# ---------------------------------------------------------------------------
# 5. continuous batching: step-time admission + slot-reuse hygiene
# ---------------------------------------------------------------------------

def test_submit_step_admits_mid_stream():
    cfg = _micro_cfg()
    params = init_model(KEY, cfg)
    eng = ServingEngine(params, cfg, n_slots=1, cache_len=64)
    a = _req(0, 0, 3, cfg.vocab_size)
    eng.submit(a)
    eng.step()          # admits a, decodes one token
    assert eng.active[0] is a and len(a.generated) == 2
    b = _req(1, 1, 4, cfg.vocab_size)
    eng.submit(b)       # queued: the only slot is busy
    eng.step()          # a reaches max_new_tokens, slot frees
    assert a.done and eng.active[0] is None
    eng.step()          # b admitted into the freed slot mid-stream
    assert eng.active[0] is b and b.generated
    for _ in range(8):
        eng.step()
    assert b.done and len(b.generated) == 4


@pytest.mark.parametrize("spec_k", [0, 4])
def test_slot_reuse_staggered_lengths_matches_solo(spec_k):
    # stateless ensemble: a request admitted into a reused slot decodes
    # exactly the stream it would decode alone
    cfg = _micro_cfg()
    params = init_model(KEY, cfg)
    sp = replicate_params(params, 7, jitter=1e-3,
                          key=jax.random.PRNGKey(7))
    spec = AggSpec(f=1, gar="krum", speculative_k=spec_k)
    reqs = [_req(0, 0, 3, cfg.vocab_size),
            _req(1, 1, 12, cfg.vocab_size),
            _req(2, 2, 6, cfg.vocab_size)]
    out = ServingEngine(sp, cfg, n_slots=2, cache_len=64,
                        ensemble=spec).run(reqs, 64)
    for seed, rid, n_new in ((0, 0, 3), (1, 1, 12), (2, 2, 6)):
        solo = ServingEngine(sp, cfg, n_slots=1, cache_len=64,
                             ensemble=spec)
        want = solo.run([_req(rid, seed, n_new, cfg.vocab_size)], 64)
        assert out[rid] == want[rid]


def test_slot_reuse_resets_stateful_history():
    # the regression this PR fixes: with a stateful rule, the stream of
    # a request admitted into a reused slot must not depend on the
    # slot's previous occupant
    cfg = _micro_cfg()
    params = init_model(KEY, cfg)
    sp = replicate_params(params, 5, jitter=1e-3,
                          key=jax.random.PRNGKey(7))
    spec = AggSpec(f=1, gar="buffered-krum")

    def stream_after(first_seed):
        reqs = [_req(0, first_seed, 3, cfg.vocab_size),
                _req(1, 1, 12, cfg.vocab_size),
                _req(2, 2, 6, cfg.vocab_size)]
        return ServingEngine(sp, cfg, n_slots=2, cache_len=64,
                             ensemble=spec).run(reqs, 64)[2]

    assert stream_after(0) == stream_after(9)


def test_reset_slot_state_zeroes_one_column():
    spec = AggSpec(f=1, gar="buffered-krum")
    state = init_ensemble_state(spec, n_replicas=5, batch=3, vocab=8)
    state = state._replace(
        history=tuple(jnp.ones_like(h) for h in state.history))
    out = reset_slot_state(state, slot=1)
    h = np.asarray(out.history[0])
    assert (h[:, :, 1] == 0.0).all()
    assert (h[:, :, 0] == 1.0).all() and (h[:, :, 2] == 1.0).all()
    assert reset_slot_state(None, 0) is None


def test_reset_slot_state_restores_reputation_column():
    # a reused slot must not inherit the previous request's trust
    # scores: its (n,) reputation column goes back to ones (neutral
    # full trust), every other slot's column is untouched
    spec = AggSpec(f=1, gar="reputation-buffered-krum")
    state = init_ensemble_state(spec, n_replicas=5, batch=3, vocab=8)
    assert state.reputation.shape == (5, 3)
    state = state._replace(
        reputation=jnp.full((5, 3), 0.25, jnp.float32),
        history=tuple(jnp.ones_like(h) for h in state.history))
    out = reset_slot_state(state, slot=1)
    rep = np.asarray(out.reputation)
    assert (rep[:, 1] == 1.0).all()
    assert (rep[:, 0] == 0.25).all() and (rep[:, 2] == 0.25).all()
    assert (np.asarray(out.history[0])[:, :, 1] == 0.0).all()


# ---------------------------------------------------------------------------
# 6. draft propose + acceptance units
# ---------------------------------------------------------------------------

def test_draft_propose_k1_never_runs_the_draft():
    cfg = _micro_cfg()
    propose = make_draft_propose(cfg, 1)
    token = jnp.asarray([3, 5], jnp.int32)
    cache = {"sentinel": jnp.zeros((2, 4))}
    block, out_cache = propose(None, cache, token,
                               jnp.zeros((2,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(block),
                                  [[3], [5]])
    assert out_cache is cache


def test_draft_propose_matches_greedy_decode():
    cfg = _micro_cfg()
    params = init_model(KEY, cfg)
    B, P, k, L = 2, 5, 4, 32
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    _, cache = prefill(params, cfg, toks, cache_len=L)
    cache_seq = jax.tree_util.tree_map(lambda x: x.copy(), cache)
    token = jnp.asarray([1, 2], jnp.int32)
    pos = jnp.full((B,), P, jnp.int32)
    block, _ = make_draft_propose(cfg, k)(params, cache, token, pos)
    np.testing.assert_array_equal(np.asarray(block[:, 0]),
                                  np.asarray(token))
    tok = token
    for j in range(1, k):
        lj, cache_seq = decode_step(params, cfg, cache_seq,
                                    tok[:, None], pos + j - 1)
        tok = jnp.argmax(lj[:, 0], axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(block[:, j]),
                                      np.asarray(tok))


def test_accept_block_semantics():
    agg = jnp.asarray([
        # slot 0: argmaxes are [2, 0, 1, 3]
        [[0., 1., 9., 0.], [9., 0., 1., 0.],
         [0., 9., 0., 1.], [0., 0., 1., 9.]],
        # slot 1: argmaxes are [1, 1, 1, 1]
        [[0., 9., 0., 0.], [0., 9., 0., 0.],
         [0., 9., 0., 0.], [0., 9., 0., 0.]],
    ], jnp.float32)
    # slot 0 drafts [2, 0, 2]: first two accepted, third rejected ->
    # emit [2, 0, 1(corrected)], count 3.  slot 1 drafts [0, 1, 1]:
    # first rejected -> emit [1], count 1.
    block = jnp.asarray([[7, 2, 0, 2], [7, 0, 1, 1]], jnp.int32)
    emitted, count, v = accept_block(block, agg)
    assert count.tolist() == [3, 1]
    assert emitted[0, :3].tolist() == [2, 0, 1]
    assert emitted[1, :1].tolist() == [1]
    np.testing.assert_array_equal(np.asarray(v),
                                  [[2, 0, 1, 3], [1, 1, 1, 1]])
    # margin widens acceptance: a near-argmax draft survives
    agg2 = agg.at[0, 2, 2].set(8.5)        # draft 2 trails argmax by 0.5
    _, count0, _ = accept_block(block, agg2)
    _, count1, _ = accept_block(block, agg2, margin=1.0)
    assert count0.tolist()[0] == 3 and count1.tolist()[0] == 4
    # k=1: no drafting, the aggregate argmax is the emission
    e1, c1, _ = accept_block(block[:, :1], agg[:, :1])
    assert c1.tolist() == [1, 1] and e1[:, 0].tolist() == [2, 1]


def test_draft_cache_view_slices_one_replica():
    stacked = {"k": jnp.arange(12.).reshape(3, 2, 2)}
    view = draft_cache_view(stacked, 1)
    np.testing.assert_array_equal(np.asarray(view["k"]),
                                  np.arange(4., 8.).reshape(2, 2))


# ---------------------------------------------------------------------------
# 7. fused distance backend through the verify path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gar", ["krum", "geomed", "bulyan-krum"])
def test_fused_backend_matches_xla_on_block_stacks(gar):
    n, f, B, k, V = 7, 1, 2, 4, 64
    stack = jax.random.normal(KEY, (n, B * k, V), jnp.float32)
    a_xla, _ = aggregate_logits(stack, f, gar, distance_backend="xla")
    a_fused, _ = aggregate_logits(stack, f, gar, distance_backend="fused")
    np.testing.assert_allclose(np.asarray(a_fused), np.asarray(a_xla),
                               rtol=0, atol=1e-5)


def test_fused_backend_through_robust_verify_step():
    cfg = _micro_cfg()
    n, f, B, P, k, L = 7, 1, 2, 5, 4, 32
    params = init_model(KEY, cfg)
    sp = replicate_params(params, n, jitter=1e-3,
                          key=jax.random.PRNGKey(7))
    toks = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    scache = jax.vmap(lambda p: prefill(p, cfg, toks, cache_len=L)[1])(sp)
    block = jax.random.randint(jax.random.PRNGKey(1), (B, k), 0,
                               cfg.vocab_size)
    pos = jnp.full((B,), P, jnp.int32)
    out = {}
    for backend in ("xla", "fused"):
        spec = AggSpec(f=f, gar="krum", distance_backend=backend,
                       speculative_k=k)
        verify = jax.jit(make_robust_verify_step(cfg, spec))
        agg, _, _, _ = verify(
            sp, jax.tree_util.tree_map(lambda x: x.copy(), scache),
            block, pos, None)
        out[backend] = np.asarray(agg)
    np.testing.assert_array_equal(out["fused"], out["xla"])
