"""Unit tests for the GAR core (paper §2.3 + §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (REGISTRY, coordinate_phase, coordinate_phase_ref,
                        get_gar, krum, pairwise_sq_dists, quorum,
                        select_indices)

KEY = jax.random.PRNGKey(0)


def _grads(n, d, key=KEY, scale=1.0):
    return scale * jax.random.normal(key, (n, d)) + 1.0


class TestPairwiseDists:
    def test_matches_naive(self):
        g = _grads(9, 64)
        d2 = pairwise_sq_dists(g)
        naive = np.array([[np.sum((g[i] - g[j]) ** 2) for j in range(9)]
                          for i in range(9)])
        np.testing.assert_allclose(d2, naive, rtol=1e-4, atol=1e-4)

    def test_zero_diagonal(self):
        d2 = pairwise_sq_dists(_grads(5, 16))
        np.testing.assert_allclose(np.diag(d2), 0.0, atol=1e-6)


class TestQuorums:
    @pytest.mark.parametrize("name,f,n_bad", [
        ("krum", 2, 6), ("brute", 2, 4), ("trimmed_mean", 3, 6)])
    def test_too_few_workers_raise(self, name, f, n_bad):
        with pytest.raises(ValueError):
            get_gar(name)(_grads(n_bad, 8), f)

    def test_bulyan_quorum(self):
        with pytest.raises(ValueError):
            get_gar("bulyan-krum")(_grads(8, 8), 2)  # needs 11
        assert quorum("bulyan-krum", 2) == 11
        assert quorum("krum", 2) == 7


class TestKrum:
    def test_selects_clump_member(self):
        # 8 clumped honest + 2 far outliers: krum must pick a clumped one
        g = jnp.concatenate([_grads(8, 32, scale=0.1),
                             100.0 + _grads(2, 32, jax.random.PRNGKey(1))])
        res = krum(g, 2)
        assert float(res.selected[-2:].sum()) == 0.0

    def test_score_formula(self):
        g = _grads(7, 16)
        f = 1
        res = krum(g, f)
        d2 = np.array(pairwise_sq_dists(g))  # writable copy
        np.fill_diagonal(d2, np.inf)
        k = 7 - f - 2
        scores = np.sort(d2, axis=1)[:, :k].sum(1)
        np.testing.assert_allclose(res.scores, scores, rtol=1e-4)
        assert int(np.argmin(scores)) == int(np.argmax(res.selected))


class TestGeoMed:
    def test_is_a_proposed_vector(self):
        g = _grads(9, 32)
        res = get_gar("geomed")(g, 2)
        dists = np.min(np.linalg.norm(np.asarray(g) -
                                      np.asarray(res.gradient), axis=1))
        assert dists < 1e-5


class TestBrute:
    def test_excludes_outliers(self):
        g = jnp.concatenate([_grads(5, 16, scale=0.1),
                             50.0 + _grads(2, 16, jax.random.PRNGKey(2))])
        res = get_gar("brute")(g, 2)
        assert float(res.selected[-2:].sum()) == 0.0
        # output = mean of the clumped 5
        np.testing.assert_allclose(res.gradient, jnp.mean(g[:5], axis=0),
                                   rtol=1e-4, atol=1e-4)


class TestCoordinateWise:
    def test_cwmed_is_median(self):
        g = _grads(7, 32)
        res = get_gar("cwmed")(g, 2)
        np.testing.assert_allclose(res.gradient, np.median(g, axis=0),
                                   rtol=1e-5)

    def test_trimmed_mean_removes_extremes(self):
        g = jnp.concatenate([_grads(7, 8, scale=0.1),
                             1e6 * jnp.ones((2, 8))])
        res = get_gar("trimmed_mean")(g, 2)
        assert float(jnp.max(jnp.abs(res.gradient))) < 10.0


class TestBulyan:
    def test_selection_count_and_uniqueness(self):
        g = _grads(11, 64)
        idx = select_indices(g, 2, base="krum")
        assert idx.shape == (7,)  # theta = 11 - 4
        assert len(set(np.asarray(idx).tolist())) == 7

    def test_coordinate_phase_windowed_equals_ref(self):
        for theta, f in [(7, 1), (9, 2), (13, 3), (5, 0)]:
            sel = jax.random.normal(jax.random.PRNGKey(theta), (theta, 512))
            np.testing.assert_allclose(coordinate_phase(sel, f),
                                       coordinate_phase_ref(sel, f),
                                       rtol=1e-5, atol=1e-6)

    def test_output_bracketed_by_selected_values(self):
        # Prop 2 mechanism: each output coordinate lies within the range of
        # the selected workers' values at that coordinate
        g = _grads(11, 128)
        f = 2
        res = get_gar("bulyan-krum")(g, f)
        idx = select_indices(g, f, base="krum")
        sel = np.asarray(g[idx])
        assert np.all(res.gradient >= sel.min(0) - 1e-5)
        assert np.all(res.gradient <= sel.max(0) + 1e-5)

    @pytest.mark.parametrize("base", ["krum", "geomed", "average", "brute"])
    def test_bases_run(self, base):
        g = _grads(7, 32)
        res = get_gar(f"bulyan-{base}")(g, 1)
        assert res.gradient.shape == (32,)
        assert bool(jnp.all(jnp.isfinite(res.gradient)))


class TestNoByzantineBehaviour:
    @pytest.mark.parametrize("name", ["krum", "geomed", "cwmed",
                                      "trimmed_mean", "bulyan-krum",
                                      "multikrum", "centered_clip"])
    def test_close_to_mean_without_adversary(self, name):
        g = _grads(15, 256, scale=0.05)
        res = get_gar(name)(g, 3)
        dev = float(jnp.linalg.norm(res.gradient - jnp.mean(g, axis=0)))
        assert dev < 1.0  # honest spread is tiny; any sane GAR is close
