"""End-to-end behaviour of the paper's system (replaces the scaffold
placeholder): the full pipeline — data -> per-worker grads -> omniscient
attack -> GAR -> optimizer — reproduces the paper's headline contrast in
one step, and the LM stack trains under Bulyan."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import ByzantineBatcher
from repro.data.synthetic import lm_batches
from repro.dist.train import DistByzantineSpec, make_loss_fn, make_train_step
from repro.models import init_model
from repro.models import simple
from repro.optim import get_optimizer
from repro.training import ByzantineSpec, ByzantineTrainer

KEY = jax.random.PRNGKey(0)


def test_headline_krum_vs_bulyan_one_round():
    """One aggregation round on real MLP gradients: the lp attack moves
    Krum's aggregate by Omega(sqrt(d)) on the attacked coordinate while
    Bulyan remains sigma-close to the honest mean (paper §3 + Prop 2)."""
    def loss_fn(params, x, y):
        return simple.classification_loss(
            simple.mnist_mlp_forward(params, x), y, params)

    devs = {}
    for gar in ("krum", "bulyan-krum"):
        spec = ByzantineSpec(n_workers=15, f=3, gar=gar,
                             attack="omniscient_lp",
                             attack_kwargs=(("gar_name", "krum"),))
        tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.1), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 83), 1)
        devs[gar] = tr.history[0]["agg_dev"]
    assert devs["krum"] > 5 * devs["bulyan-krum"]


def test_lm_training_under_attack_loss_decreases():
    """A small transformer trains on the Markov LM stream with Bulyan under
    the linf attack: loss must decrease (convergence claim, Cor. 2)."""
    cfg = get_reduced("llama3_2_3b")
    params = init_model(KEY, cfg)
    opt = get_optimizer("adam", 3e-3)
    spec = DistByzantineSpec(f=1, gar="bulyan-krum",
                             attack="omniscient_linf")
    step = jax.jit(make_train_step(cfg, spec, opt))
    state = opt.init(params)
    n, b, s = 7, 2, 64
    stream_vocab = 128  # small enough that 40 steps cover the table
    losses = []
    for t in range(40):
        toks = np.stack([lm_batches(stream_vocab, b, s, t * n + w,
                                    seed=3)[0] for w in range(n)])
        labs = np.stack([lm_batches(stream_vocab, b, s, t * n + w,
                                    seed=3)[1] for w in range(n)])
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
