"""Attack tests: the §3.2 mechanism, gamma_m search, and scaling claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (find_gamma_max, gamma_closed_form, get_attack,
                        get_gar, make_selection_checker)

KEY = jax.random.PRNGKey(3)


def _honest(n_h, d, key=KEY):
    return jax.random.normal(key, (n_h, d)) * 0.5 + 1.0


class TestGammaSearch:
    def test_selected_at_found_gamma_not_above(self):
        n_h, f, d = 9, 2, 256
        honest = _honest(n_h, d)
        check = make_selection_checker("krum", f)
        e = jnp.zeros((d,)).at[0].set(1.0)
        g = float(find_gamma_max(honest, f, e, check))
        assert g > 0

        def selected(gamma):
            byz = jnp.mean(honest, 0)[None] + gamma * e[None]
            return bool(check(jnp.concatenate(
                [honest, jnp.repeat(byz, f, 0)])))

        assert selected(g * 0.95)
        assert not selected(g * 1.50)

    def test_gamma_grows_with_sqrt_d(self):
        """The paper's core claim: gamma_m = Omega(sqrt(d)) for p=2."""
        f = 2
        gs = []
        for d in (64, 256, 1024):
            honest = _honest(9, d, jax.random.fold_in(KEY, d))
            check = make_selection_checker("krum", f)
            e = jnp.zeros((d,)).at[0].set(1.0)
            gs.append(float(find_gamma_max(honest, f, e, check)))
        # quadrupling d should roughly double gamma
        assert gs[1] / gs[0] > 1.5
        assert gs[2] / gs[1] > 1.5

    def test_closed_form_order_of_magnitude(self):
        f, d = 2, 1024
        honest = _honest(9, d)
        check = make_selection_checker("krum", f)
        e = jnp.zeros((d,)).at[0].set(1.0)
        g = float(find_gamma_max(honest, f, e, check))
        db = float(2 / np.sqrt(np.pi) * jnp.mean(jnp.std(honest, axis=0)))
        approx = gamma_closed_form("krum", d, f, db)
        assert 0.1 < g / approx < 10.0


class TestAttackEffects:
    def test_krum_fully_poisoned_bulyan_clamped(self):
        """The headline result: the attack drives Krum's output by
        Omega(sqrt(d)) on one coordinate; Bulyan stays within the honest
        coordinate spread (Prop 2)."""
        n_h, f, d = 9, 2, 2048
        honest = _honest(n_h, d)
        byz = get_attack("omniscient_lp")(honest, f, None, gar_name="krum")
        full = jnp.concatenate([honest, byz])
        mean = jnp.mean(honest, axis=0)
        krum_dev = float(jnp.max(jnp.abs(
            get_gar("krum")(full, f).gradient - mean)))
        bul_dev = float(jnp.max(jnp.abs(
            get_gar("bulyan-krum")(full, f).gradient - mean)))
        sigma_c = float(jnp.mean(jnp.std(honest, axis=0)))
        assert krum_dev > 10 * sigma_c          # poisoned ~ sqrt(d) sigma
        assert bul_dev < 10 * sigma_c           # clamped ~ sigma
        assert krum_dev / bul_dev > 5.0

    @pytest.mark.parametrize("attack,kw", [
        ("alie", {}), ("ipm", {}), ("signflip", {}), ("zero", {}),
        ("mimic", {}), ("omniscient_linf", {"gamma": "closed"}),
        ("omniscient_lp", {"gamma": "closed"}),
        ("omniscient_lp", {"gamma": "closed", "coord": "top"}),
    ])
    def test_attacks_produce_valid_submissions(self, attack, kw):
        honest = _honest(9, 128)
        byz = get_attack(attack)(honest, 2, jax.random.PRNGKey(9), **kw)
        assert byz.shape == (2, 128)
        assert bool(jnp.all(jnp.isfinite(byz)))

    def test_random_attack_needs_key(self):
        honest = _honest(9, 64)
        byz = get_attack("random")(honest, 2, jax.random.PRNGKey(1))
        assert byz.shape == (2, 64)

    def test_averaging_fully_controlled(self):
        """Lemma 1 of Blanchard et al.: a single Byzantine worker drives a
        linear GAR anywhere."""
        honest = _honest(10, 32)
        target = 77.0 * jnp.ones((32,))
        n = 11
        byz = (n * target - jnp.sum(honest, axis=0))[None, :]
        full = jnp.concatenate([honest, byz])
        out = get_gar("average")(full, 1).gradient
        np.testing.assert_allclose(out, target, rtol=1e-3)
