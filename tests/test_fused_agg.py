"""Fused-megakernel battery: parity, bitwise structure, tile edges.

The fused backend has three contracts this file pins:

* **parity** — ``fused_aggregate`` matches the registry's dense rule to
  1e-4 for every mode it lowers, on the flat path and (through
  ``distributed_aggregate``) on single- and multi-leaf trees;
* **bitwise structure** — the megakernel and the unfused kernel pair
  (``pairwise_gram_partial`` + ``select_weights`` +
  ``fused_coordinate``) share one selection function and one combine
  body, so at the same ``block_d`` their outputs are *bitwise* equal in
  interpret mode — any drift means the two lowerings diverged;
* **tile edges** — d below / at / just past the block width, odd and
  even worker counts (the median branch), the Bulyan quorum edge
  ``n = 4f + 3``, and the fp32-accumulation contract on bf16 inputs.

Property-based cases (random (n, f, d) grids) run when ``hypothesis``
is installed and skip cleanly otherwise — the CPU CI container does not
ship it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg.fused import FUSED_BASES, fused_name
from repro.agg.registry import resolve_rule
from repro.audit.sweep import audit_roster
from repro.dist.robust import (distributed_aggregate,
                               resolve_distance_backend)
from repro.kernels.fused_agg import (COORD_MODES, DIST_MODES, FUSED_MODES,
                                     fused_aggregate, fused_coordinate,
                                     select_weights)
from repro.kernels.pairwise_gram import (finalize_dists,
                                         pairwise_gram_partial)
from repro.kernels.probes import fused_fp32_contract_error

KEY = jax.random.PRNGKey(23)


def _stack(n, d, key=KEY, dtype=jnp.float32):
    return (jax.random.normal(key, (n, d), jnp.float32) * 0.5
            + 1.0).astype(dtype)


def _tree(n, key=KEY, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": jax.random.normal(k1, (n, 7, 5)).astype(dtype),
            "b": jax.random.normal(k2, (n, 130)).astype(dtype),  # pads
            "c": jax.random.normal(k3, (n, 3)).astype(dtype)}


class TestDenseParity:
    """fused_aggregate vs the registry's dense rule, every mode."""

    @pytest.mark.parametrize("mode", FUSED_MODES)
    def test_matches_dense_rule(self, mode):
        n, f = 11, 2
        g = _stack(n, 300)
        agg, sel, scores = fused_aggregate(g, f, mode=mode, block_d=128,
                                           interpret=True)
        want = resolve_rule(mode).dense_fn(g, f)
        np.testing.assert_allclose(np.asarray(agg),
                                   np.asarray(want.gradient), atol=1e-4)
        np.testing.assert_allclose(np.asarray(sel),
                                   np.asarray(want.selected), atol=1e-4)

    @pytest.mark.parametrize("mode", ["krum", "multikrum", "geomed"])
    def test_scores_match(self, mode):
        n, f = 9, 1
        g = _stack(n, 200)
        _, _, scores = fused_aggregate(g, f, mode=mode, block_d=128,
                                       interpret=True)
        want = resolve_rule(mode).dense_fn(g, f).scores
        np.testing.assert_allclose(np.asarray(scores), np.asarray(want),
                                   rtol=1e-4)

    @pytest.mark.parametrize("mode", FUSED_MODES)
    def test_registry_composite_matches_base(self, mode):
        n, f = 11, 2
        g = _stack(n, 150)
        got = resolve_rule(f"fused-{mode}").dense_fn(g, f)
        want = resolve_rule(mode).dense_fn(g, f)
        np.testing.assert_allclose(np.asarray(got.gradient),
                                   np.asarray(want.gradient), atol=1e-4)
        np.testing.assert_allclose(np.asarray(got.selected),
                                   np.asarray(want.selected), atol=1e-4)


class TestBitwiseFusedVsUnfused:
    """Megakernel == gram kernel + select_weights + pair kernel, bitwise."""

    @pytest.mark.parametrize("mode", DIST_MODES)
    def test_dist_modes_bitwise(self, mode):
        n, f, d = 11, 2, 257
        g = _stack(n, d)
        agg, sel, scores = fused_aggregate(g, f, mode=mode, block_d=128,
                                           interpret=True)
        d2 = finalize_dists(pairwise_gram_partial(g, block_d=128,
                                                  interpret=True))
        w, sel2, scores2 = select_weights(d2, n, f, mode)
        agg2 = fused_coordinate(g, w, f, mode=mode, block_d=128,
                                interpret=True)
        assert np.array_equal(np.asarray(agg), np.asarray(agg2))
        assert np.array_equal(np.asarray(sel), np.asarray(sel2[0]))
        assert np.array_equal(np.asarray(scores), np.asarray(scores2[0]))

    @pytest.mark.parametrize("mode", COORD_MODES)
    def test_coord_modes_bitwise(self, mode):
        n, f, d = 9, 2, 257
        g = _stack(n, d)
        agg, _, _ = fused_aggregate(g, f, mode=mode, block_d=128,
                                    interpret=True)
        agg2 = fused_coordinate(g, None, f, mode=mode, block_d=128,
                                interpret=True)
        assert np.array_equal(np.asarray(agg), np.asarray(agg2))


class TestTileBoundaries:
    """d vs block_d edges, odd/even n, block-size invariance."""

    @pytest.mark.parametrize("d", [1, 100, 128, 129, 257])
    @pytest.mark.parametrize("mode", ["bulyan-krum", "cwmed"])
    def test_d_edges(self, mode, d):
        n, f = 11, 2
        g = _stack(n, d)
        agg, _, _ = fused_aggregate(g, f, mode=mode, block_d=128,
                                    interpret=True)
        want = resolve_rule(mode).dense_fn(g, f).gradient
        assert agg.shape == (d,)
        np.testing.assert_allclose(np.asarray(agg), np.asarray(want),
                                   atol=1e-4)

    @pytest.mark.parametrize("n", [5, 6])
    def test_median_branch_odd_even(self, n):
        g = _stack(n, 130)
        agg, _, _ = fused_aggregate(g, 1, mode="cwmed", block_d=128,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(agg),
                                   np.asarray(jnp.median(g, axis=0)),
                                   atol=1e-6)

    @pytest.mark.parametrize("mode", ["krum", "bulyan-krum",
                                      "trimmed_mean"])
    def test_block_size_invariance(self, mode):
        n, f, d = 11, 2, 300
        g = _stack(n, d)
        a128, _, _ = fused_aggregate(g, f, mode=mode, block_d=128,
                                     interpret=True)
        a512, _, _ = fused_aggregate(g, f, mode=mode, block_d=512,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(a128), np.asarray(a512),
                                   atol=1e-5)


class TestTreePaths:
    """distance_backend="fused" through the sharded engine."""

    @pytest.mark.parametrize("gar", ["krum", "multikrum", "geomed",
                                     "cwmed", "trimmed_mean",
                                     "bulyan-krum", "bulyan-geomed"])
    def test_multi_leaf_matches_xla(self, gar):
        n, f = 11, 2
        tree = _tree(n)
        ax, rx = distributed_aggregate(tree, f, gar,
                                       distance_backend="xla")
        af, rf = distributed_aggregate(tree, f, gar,
                                       distance_backend="fused")
        for x, y in zip(jax.tree_util.tree_leaves(ax),
                        jax.tree_util.tree_leaves(af)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-4)
        np.testing.assert_allclose(np.asarray(rx.selected),
                                   np.asarray(rf.selected), atol=1e-4)

    def test_single_leaf_takes_megakernel(self, monkeypatch):
        import repro.agg.fused as fused_mod
        n, f = 11, 2
        tree = {"w": _tree(n)["b"]}
        calls = []
        orig = fused_mod.fused_aggregate
        monkeypatch.setattr(
            fused_mod, "fused_aggregate",
            lambda *a, **k: calls.append(1) or orig(*a, **k))
        a1, _ = distributed_aggregate(tree, f, "bulyan-krum",
                                      distance_backend="fused")
        assert calls, "single-leaf tree should route to the megakernel"
        a2, _ = distributed_aggregate(tree, f, "bulyan-krum",
                                      distance_backend="xla")
        np.testing.assert_allclose(np.asarray(a1["w"]),
                                   np.asarray(a2["w"]), atol=1e-4)

    def test_fused_gar_name_direct(self):
        n, f = 9, 1
        tree = _tree(n)
        a1, r1 = distributed_aggregate(tree, f, "fused-krum")
        a2, r2 = distributed_aggregate(tree, f, "krum")
        for x, y in zip(jax.tree_util.tree_leaves(a1),
                        jax.tree_util.tree_leaves(a2)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5)
        assert np.array_equal(np.asarray(r1.selected),
                              np.asarray(r2.selected))

    def test_non_lowerable_rule_still_runs(self):
        n, f = 9, 1
        tree = _tree(n)
        ab, _ = distributed_aggregate(tree, f, "brute",
                                      distance_backend="fused")
        ax, _ = distributed_aggregate(tree, f, "brute",
                                      distance_backend="xla")
        for x, y in zip(jax.tree_util.tree_leaves(ab),
                        jax.tree_util.tree_leaves(ax)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-4)

    def test_backend_resolution(self):
        assert resolve_distance_backend("fused") == "fused"
        with pytest.raises(ValueError, match="fused"):
            resolve_distance_backend("fussed")


class TestRegistry:
    """fused-* names resolve, reject, and appear in the audit roster."""

    def test_quorum_carries_over(self):
        assert resolve_rule("fused-krum").min_n(2) == 7
        assert resolve_rule("fused-bulyan-krum").min_n(2) == 11
        assert resolve_rule("fused-cwmed").min_n(2) == 5

    def test_unknown_base_rejected(self):
        with pytest.raises(KeyError, match="no fused lowering"):
            resolve_rule("fused-brute")
        with pytest.raises(KeyError, match="unknown GAR"):
            resolve_rule("fusedkrum")

    def test_canonical_quorum_message(self):
        from repro.agg.specs import check_quorum
        with pytest.raises(
                ValueError,
                match=r"fused-bulyan-krum requires n >= 11 for f=2, "
                      r"got n=10"):
            check_quorum("fused-bulyan-krum", 10, 2)

    def test_audit_roster_contains_fused(self):
        roster = audit_roster()
        for base in FUSED_BASES:
            assert f"fused-{base}" in roster
        assert "stale-fused-krum" in roster

    def test_fused_name_mapping(self):
        assert fused_name("krum") == "fused-krum"
        assert fused_name("bulyan-geomed") == "fused-bulyan-geomed"
        assert fused_name("stale-krum") == "stale-fused-krum"
        assert fused_name("stale-exp-cwmed") == "stale-exp-fused-cwmed"
        assert fused_name("buffered-krum") == "buffered-fused-krum"
        assert fused_name("brute") is None
        assert fused_name("average") is None
        assert fused_name("centered_clip") is None
        assert fused_name("stale-brute") is None
        # idempotent on already-fused names
        assert fused_name("fused-krum") == "fused-krum"

    def test_stale_fused_composite_runs(self):
        from repro.agg.state import init_state
        n, f = 9, 1
        g = _stack(n, 40)
        rule = resolve_rule("stale-fused-krum")
        assert rule.stateful
        state = init_state(rule, g)
        res, _ = rule.dense_fn(g, f, state)
        want = resolve_rule("fused-krum").dense_fn(g, f)
        np.testing.assert_allclose(np.asarray(res.gradient),
                                   np.asarray(want.gradient), atol=1e-5)


class TestQuorumEdge:
    """Bulyan at exactly n = 4f + 3 (theta = 2f + 3, beta = 3)."""

    @pytest.mark.parametrize("f", [1, 2])
    @pytest.mark.parametrize("mode", ["bulyan-krum", "bulyan-geomed"])
    def test_exact_quorum_parity(self, mode, f):
        n = 4 * f + 3
        g = _stack(n, 200)
        agg, sel, _ = fused_aggregate(g, f, mode=mode, block_d=128,
                                      interpret=True)
        want = resolve_rule(mode).dense_fn(g, f)
        np.testing.assert_allclose(np.asarray(agg),
                                   np.asarray(want.gradient), atol=1e-4)
        assert np.array_equal(np.asarray(sel), np.asarray(want.selected))

    def test_below_quorum_raises(self):
        g = _stack(6, 40)
        with pytest.raises(ValueError, match="bulyan requires n >= 4f"):
            fused_aggregate(g, 1, mode="bulyan-krum", interpret=True)
        with pytest.raises(ValueError, match="krum needs"):
            fused_aggregate(g[:3], 1, mode="krum", interpret=True)
        with pytest.raises(KeyError, match="unknown fused mode"):
            fused_aggregate(g, 1, mode="brute", interpret=True)


class TestFp32Contract:
    """bf16 streams, fp32 accumulation — probed like the other kernels."""

    @pytest.mark.parametrize("mode", ["bulyan-krum", "krum",
                                      "trimmed_mean"])
    def test_probe_under_tolerance(self, mode):
        err = fused_fp32_contract_error(n=11, f=2, d=512, mode=mode,
                                        block_d=256, interpret=True)
        assert err < 1e-4


class TestPropertyBased:
    """Random (n, f, d) grids under hypothesis (skips when missing)."""

    def test_random_shapes_parity(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=8, deadline=None, derandomize=True)
        @given(f=st.integers(0, 2), extra=st.integers(0, 3),
               d=st.integers(1, 200), seed=st.integers(0, 2**31 - 1),
               mode=st.sampled_from(FUSED_MODES))
        def check(f, extra, d, seed, mode):
            n = 4 * f + 3 + extra
            g = _stack(n, d, key=jax.random.PRNGKey(seed))
            agg, sel, _ = fused_aggregate(g, f, mode=mode, block_d=128,
                                          interpret=True)
            want = resolve_rule(mode).dense_fn(g, f)
            np.testing.assert_allclose(np.asarray(agg),
                                       np.asarray(want.gradient),
                                       atol=1e-4)
            # hull invariant: every coordinate within the worker range
            lo = np.min(np.asarray(g), axis=0) - 1e-4
            hi = np.max(np.asarray(g), axis=0) + 1e-4
            a = np.asarray(agg)
            assert ((a >= lo) & (a <= hi)).all()

        check()
