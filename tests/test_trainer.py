"""End-to-end Byzantine training behaviour (the paper's §5 claims, in
miniature): the attack poisons Krum's aggregate by Omega(sqrt(d)); Bulyan's
stays at honest-noise level; clean training learns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ByzantineBatcher
from repro.data.synthetic import mnist_like
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import ByzantineSpec, ByzantineTrainer

KEY = jax.random.PRNGKey(1)


def loss_fn(params, x, y):
    return simple.classification_loss(
        simple.mnist_mlp_forward(params, x), y, params)


def _eval(params):
    xe, ye = mnist_like(1000, 10 ** 6, seed=0)
    return float(simple.accuracy(
        simple.mnist_mlp_forward(params, jnp.asarray(xe)), jnp.asarray(ye)))


def test_clean_training_learns():
    spec = ByzantineSpec(n_workers=7, f=0, gar="average", attack="none")
    tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                          get_optimizer("sgd", fading_lr(1.0, 10000)), spec)
    tr.run(ByzantineBatcher("mnist", 7, 32), 25)
    assert _eval(tr.params) > 0.9


def test_attack_poisons_krum_but_not_bulyan_step0():
    devs = {}
    for gar in ("krum", "bulyan-krum"):
        spec = ByzantineSpec(n_workers=15, f=3, gar=gar,
                             attack="omniscient_lp",
                             attack_kwargs=(("gar_name", "krum"),))
        tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                              get_optimizer("sgd", 0.1), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 64), 1)
        devs[gar] = tr.history[0]["agg_dev"]
    assert devs["krum"] > 5 * devs["bulyan-krum"]
    assert devs["krum"] > 1.0


def test_byzantine_weight_metrics():
    spec = ByzantineSpec(n_workers=15, f=3, gar="krum",
                         attack="omniscient_lp",
                         attack_kwargs=(("gar_name", "krum"),))
    tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                          get_optimizer("sgd", 0.05), spec)
    tr.run(ByzantineBatcher("mnist", spec.n_honest, 64), 2)
    assert tr.history[0]["byz_weight"] >= 1.0  # the attack is selected


def test_bulyan_under_attack_still_learns():
    spec = ByzantineSpec(n_workers=15, f=3, gar="bulyan-krum",
                         attack="omniscient_lp",
                         attack_kwargs=(("gar_name", "krum"),
                                        ("coord", "top")))
    tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                          get_optimizer("sgd", fading_lr(1.0, 10000)), spec)
    tr.run(ByzantineBatcher("mnist", spec.n_honest, 64), 25)
    assert _eval(tr.params) > 0.85


def test_quorum_validation():
    with pytest.raises(ValueError):
        ByzantineSpec(n_workers=9, f=3, gar="bulyan-krum").validate()


def test_attack_until_epoch_switches_off():
    spec = ByzantineSpec(n_workers=15, f=3, gar="krum",
                         attack="omniscient_lp",
                         attack_kwargs=(("gar_name", "krum"),))
    tr = ByzantineTrainer(loss_fn, simple.init_mnist_mlp(KEY),
                          get_optimizer("sgd", 0.05), spec)
    tr.run(ByzantineBatcher("mnist", spec.n_honest, 64), 4, attack_until=2)
    assert tr.history[0]["byz_weight"] >= 1.0
    assert tr.history[3]["byz_weight"] == 0.0
