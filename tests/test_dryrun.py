"""Dry-run smoke: one reduced (arch x shape) lower+compile in a 512-device
subprocess, validating the artifact schema the roofline analysis consumes.
The full-size matrix is produced by repro.launch.sweep (see EXPERIMENTS.md).
"""
import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                           *args], capture_output=True, text=True,
                          timeout=timeout, env=env)


@pytest.mark.slow
def test_reduced_dryrun_train_artifact():
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "a.json")
        r = _run(["--arch", "mamba2-130m", "--shape", "train_4k",
                  "--reduced", "--out", out])
        assert r.returncode == 0, r.stderr[-3000:]
        rec = json.load(open(out))
    assert rec["mesh"] == "16x16"
    roof = rec["roofline"]
    for k in ("compute_s", "memory_s", "collective_s", "dominant",
              "useful_flops_ratio"):
        assert k in roof
    assert roof["compute_s"] > 0
    assert sum(v["count"] for v in rec["collectives"].values()) > 0


@pytest.mark.slow
def test_reduced_dryrun_multipod_decode():
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "b.json")
        r = _run(["--arch", "gemma3-1b", "--shape", "decode_32k",
                  "--reduced", "--multi-pod", "--out", out])
        assert r.returncode == 0, r.stderr[-3000:]
        rec = json.load(open(out))
    assert rec["mesh"] == "2x16x16"
    assert rec["multi_pod"] is True


@pytest.mark.slow
def test_reduced_dryrun_robust_ensemble_decode():
    """--serve-gar: the robust ensemble decode step lowers + compiles on
    the production mesh with the replica axis on ``data``."""
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "c.json")
        r = _run(["--arch", "gemma3-1b", "--shape", "decode_32k",
                  "--reduced", "--serve-gar", "bulyan-krum",
                  "--serve-f", "1", "--serve-replicas", "7",
                  "--out", out])
        assert r.returncode == 0, r.stderr[-3000:]
        rec = json.load(open(out))
    assert rec["serve_gar"] == "bulyan-krum"
    assert rec["serve_replicas"] == 7
    assert rec["hlo_lines"] > 0


@pytest.mark.slow
def test_reduced_dryrun_async_stale_train():
    """--async-tau + --gar stale-*: the asynchronous bounded-staleness
    train step lowers + compiles on the production mesh with the
    GradientBus-carrying AggState initialized via eval_shape (nothing
    materialized), including the delay-exploiting in-graph attack."""
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "d.json")
        r = _run(["--arch", "mamba2-130m", "--shape", "train_4k",
                  "--reduced", "--async-tau", "3", "--async-schedule",
                  "fixed", "--gar", "stale-bulyan-krum", "--attack",
                  "stale_replay", "--out", out])
        assert r.returncode == 0, r.stderr[-3000:]
        rec = json.load(open(out))
    assert rec["async_tau"] == 3
    assert rec["gar"] == "stale-bulyan-krum"
    assert rec["roofline"]["compute_s"] > 0
    assert rec["hlo_lines"] > 0


def test_long_500k_skip_rules():
    from repro.configs import shape_applicable
    assert shape_applicable("mamba2-130m", "long_500k")
    assert shape_applicable("mixtral-8x22b", "long_500k")
    assert shape_applicable("jamba-1.5-large-398b", "long_500k")
    assert not shape_applicable("gemma-2b", "long_500k")
    assert not shape_applicable("whisper-medium", "long_500k")
    assert not shape_applicable("llama-3.2-vision-11b", "long_500k")
    assert shape_applicable("gemma-2b", "train_4k")
