"""Tier-1 wrapper around scripts/docs_lint.py: the README and docs must
exist, their python blocks must compile, their ``repro`` imports must
resolve, and every repo path they mention must exist."""
import os
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

import docs_lint  # noqa: E402


def test_front_door_exists():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "dist-runtime.md").exists()
    assert (REPO / "docs" / "serving.md").exists()
    assert (REPO / "docs" / "async-runtime.md").exists()
    assert (REPO / "docs" / "audit.md").exists()
    assert (REPO / "docs" / "kernels.md").exists()
    assert (REPO / "docs" / "reputation.md").exists()
    assert (REPO / "docs" / "observability.md").exists()


@pytest.mark.parametrize("doc", ["README.md", "docs/dist-runtime.md",
                                 "docs/aggregation.md", "docs/serving.md",
                                 "docs/async-runtime.md", "docs/audit.md",
                                 "docs/kernels.md", "docs/reputation.md",
                                 "docs/observability.md"])
def test_doc_lints_clean(doc):
    errors = docs_lint.lint_file(REPO / doc)
    assert not errors, "\n".join(errors)


def test_lint_catches_bad_snippet(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("```python\nfrom repro.dist import no_such_symbol\n"
                   "def broken(:\n```\nsee src/repro/nope.py\n")
    # lint_file reports paths relative to the repo; copy under docs/ would
    # pollute the tree, so monkeypatch the root instead
    old = docs_lint.REPO
    docs_lint.REPO = tmp_path
    try:
        errors = docs_lint.lint_file(bad)
    finally:
        docs_lint.REPO = old
    assert any("does not compile" in e for e in errors)
    assert any("nope.py missing" in e for e in errors)


@pytest.mark.parametrize("pkg", ["repro.dist", "repro.kernels",
                                 "repro.serving", "repro.dist.serve",
                                 "repro.dist.serve_robust",
                                 "repro.serving.speculative",
                                 "repro.dist.async_train",
                                 "repro.agg.staleness",
                                 "repro.agg.reputation",
                                 "repro.audit", "repro.audit.invariants",
                                 "repro.audit.sweep",
                                 "repro.audit.leeway",
                                 "repro.kernels.probes",
                                 "repro.kernels.common",
                                 "repro.kernels.fused_agg",
                                 "repro.agg.fused",
                                 "repro.obs", "repro.obs.schema",
                                 "repro.obs.buffer",
                                 "repro.obs.forensics",
                                 "repro.obs.detect", "repro.obs.trace",
                                 "repro.obs.export"])
def test_public_symbols_documented(pkg):
    """Acceptance criterion: every public symbol exported by repro.dist
    (and repro.kernels, and the serving stack) carries a docstring, and
    __all__ is accurate."""
    import importlib
    mod = importlib.import_module(pkg)
    assert mod.__all__ == sorted(set(mod.__all__)), "unsorted/dup __all__"
    for name in mod.__all__:
        obj = getattr(mod, name)
        assert getattr(obj, "__doc__", None), f"{pkg}.{name} undocumented"


def test_serving_doc_covers_exported_api():
    """docs/serving.md must not drift from the serving API surface: every
    symbol exported by repro.dist.serve_robust and repro.dist.serve (and
    the engine's entry points) has to be mentioned by name."""
    import importlib
    text = (REPO / "docs" / "serving.md").read_text()
    names = set()
    for pkg in ("repro.dist.serve_robust", "repro.dist.serve",
                "repro.serving", "repro.serving.speculative"):
        names.update(importlib.import_module(pkg).__all__)
    missing = sorted(n for n in names if n not in text)
    assert not missing, f"docs/serving.md misses exported API: {missing}"


def test_async_doc_covers_exported_api():
    """docs/async-runtime.md must not drift from the async API surface:
    every symbol exported by repro.dist.async_train and
    repro.agg.staleness has to be mentioned by name."""
    import importlib
    text = (REPO / "docs" / "async-runtime.md").read_text()
    names = set()
    for pkg in ("repro.dist.async_train", "repro.agg.staleness"):
        names.update(importlib.import_module(pkg).__all__)
    missing = sorted(n for n in names if n not in text)
    assert not missing, f"docs/async-runtime.md misses exported API: " \
                        f"{missing}"


def test_audit_doc_covers_exported_api():
    """docs/audit.md must not drift from the audit API surface: every
    symbol exported by repro.audit and its submodules has to be
    mentioned by name."""
    import importlib
    text = (REPO / "docs" / "audit.md").read_text()
    names = set()
    for pkg in ("repro.audit", "repro.audit.invariants",
                "repro.audit.sweep", "repro.audit.leeway"):
        names.update(importlib.import_module(pkg).__all__)
    missing = sorted(n for n in names if n not in text)
    assert not missing, f"docs/audit.md misses exported API: {missing}"


def test_reputation_doc_covers_exported_api():
    """docs/reputation.md must not drift from the reputation API surface:
    every symbol exported by repro.agg.reputation has to be mentioned by
    name."""
    import importlib
    text = (REPO / "docs" / "reputation.md").read_text()
    names = set(importlib.import_module("repro.agg.reputation").__all__)
    missing = sorted(n for n in names if n not in text)
    assert not missing, f"docs/reputation.md misses exported API: {missing}"


def test_kernels_doc_covers_exported_api():
    """docs/kernels.md must not drift from the kernel API surface: every
    symbol exported by repro.kernels, repro.kernels.fused_agg and
    repro.kernels.common has to be mentioned by name."""
    import importlib
    text = (REPO / "docs" / "kernels.md").read_text()
    names = set()
    for pkg in ("repro.kernels", "repro.kernels.fused_agg",
                "repro.kernels.common"):
        names.update(importlib.import_module(pkg).__all__)
    missing = sorted(n for n in names if n not in text)
    assert not missing, f"docs/kernels.md misses exported API: {missing}"


def test_obs_doc_covers_exported_api():
    """docs/observability.md must not drift from the telemetry API
    surface: every symbol exported by the repro.obs modules has to be
    mentioned by name."""
    import importlib
    text = (REPO / "docs" / "observability.md").read_text()
    names = set()
    for pkg in ("repro.obs", "repro.obs.schema", "repro.obs.buffer",
                "repro.obs.forensics", "repro.obs.detect",
                "repro.obs.trace", "repro.obs.export"):
        names.update(importlib.import_module(pkg).__all__)
    missing = sorted(n for n in names if n not in text)
    assert not missing, f"docs/observability.md misses exported API: " \
                        f"{missing}"


def test_changes_log_mentions_every_pr():
    """CHANGES.md is the cross-session ledger — it must keep one line per
    shipped PR (see the repo growth protocol)."""
    text = (REPO / "CHANGES.md").read_text()
    assert "PR 1" in text
