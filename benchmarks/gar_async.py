"""Sync vs async convergence-per-wall-clock across aggregation rules.

The asynchronous runtime trades per-step semantics (stale gradients in
the stack) for the removal of the per-step barrier: in a real deployment
the async step's wall-clock is set by the *fastest* workers while the
synchronous step waits for the slowest straggler.  The single-host
simulation here pays the same compute either way, so the CSV reports the
two halves of that trade separately:

  * measured us/call of the jitted step (sync vs async bookkeeping
    overhead — the bus select/write is the only extra work);
  * accuracy after a fixed step budget under bounded staleness tau
    (what asynchrony costs in convergence per *step*), from which the
    derived column computes ``straggler_speedup`` — the wall-clock
    advantage the async run banks once steps are priced by the fastest
    worker instead of the slowest (x(tau+1) on the staggered schedule).

Rows: ``gar_async/<rule>_tau<k>`` with the ``backend`` column tagging
``sync`` / ``async`` variants.  Attacked rows add the stale-replay
adversary so the staleness-aware rules' resilience shows up in the perf
trajectory alongside ``gar_backends`` / ``gar_buffered`` /
``serve_robust``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_eval, mnist_loss
from repro.data import ByzantineBatcher
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import (AsyncByzantineTrainer, ByzantineSpec,
                            ByzantineTrainer)


def _train(gar: str, attack: str, tau: int, steps: int, *, n_honest=30,
           f=9, seed=1):
    n = n_honest + (f if attack != "none" else 0)
    spec = ByzantineSpec(
        n_workers=n, f=f if attack != "none" else 0, gar=gar,
        attack=attack, async_tau=tau, seed=seed,
        attack_kwargs=(("scale", -4.0),) if attack == "stale_replay"
        else ())
    cls = AsyncByzantineTrainer if tau is not None else ByzantineTrainer
    tr = cls(mnist_loss, simple.init_mnist_mlp(jax.random.PRNGKey(seed)),
             get_optimizer("sgd", fading_lr(1.0, 10000)), spec)
    batcher = ByzantineBatcher("mnist", spec.n_honest, 32, seed=seed,
                               noise=0.5)
    tr.run(batcher, 3)                      # compile + warm the carry
    t0 = time.time()
    tr.run(batcher, steps, start_step=3)
    wall = time.time() - t0
    acc = float(make_eval("mnist")(tr.params))
    return 1e6 * wall / steps, acc


def main(steps: int = 60, taus=(0, 3), seed: int = 1) -> None:
    """One row per (rule, tau, sync/async) on the miniature MNIST
    protocol: us/step measured, accuracy + the straggler-priced speedup
    derived.

    Args:
      steps: measured training steps per row (after a 3-step warmup).
      taus: staleness bounds for the async rows (0 = the degenerate
        sync-equivalent case, the overhead measurement).
      seed: PRNG seed threaded to init, batching and the attack noise —
        the accuracy columns are deterministic per seed.

    Returns:
      None (emits CSV rows).
    """
    rules = (("average", "none"), ("krum", "stale_replay"),
             ("stale-krum", "stale_replay"),
             ("stale-bulyan-krum", "stale_replay"))
    sync_rows = {}
    for gar, attack in rules:
        base = gar.replace("stale-", "")
        if (base, attack) not in sync_rows:
            sync_rows[(base, attack)] = _train(base, attack, None, steps,
                                               seed=seed)
            us0, acc0 = sync_rows[(base, attack)]
            emit(f"gar_async/{base}_sync", us0, f"acc={acc0:.3f}", "sync")
        us_sync, acc_sync = sync_rows[(base, attack)]
        for tau in taus:
            us, acc = _train(gar, attack, tau, steps, seed=seed)
            # per-step wall-clock if steps are priced by the fastest
            # worker (async) vs the slowest straggler (sync barrier):
            # the staggered schedule lets a tau-stale worker lag tau+1
            # steps behind the barrier pace
            speedup = (tau + 1) * us_sync / us
            emit(f"gar_async/{gar}_tau{tau}", us,
                 f"acc={acc:.3f};sync_acc={acc_sync:.3f};"
                 f"straggler_speedup={speedup:.2f}", "async")


if __name__ == "__main__":
    main()
