"""Paper Figs. 4/5: Bulyan vs Krum/GeoMed under attack, 30 honest + 9
Byzantine (n = 39 = 4f+3 minimal Bulyan quorum), at two learning rates.

Expected ordering (paper): Bulyan tracks the clean-average reference;
Krum/GeoMed lose more convergence speed at the higher rate.
"""
from __future__ import annotations

from benchmarks.common import emit, run_experiment


def main(steps: int = 120) -> None:
    linf = (("gamma", "closed"), ("direction", "anti"), ("margin", 0.8))
    for eta0 in (0.3, 0.1):
        ref = run_experiment(kind="mnist", gar="average", attack="none",
                             n_honest=30, f=0, steps=steps, eta0=eta0)
        emit(f"fig4/average_clean_eta{eta0}", ref["us_per_step"],
             f"mean_acc={ref['mean_acc']:.3f};to90={ref['steps_to_90']}")
        for gar in ("krum", "geomed", "bulyan-krum"):
            base = gar.replace("bulyan-", "")
            r = run_experiment(kind="mnist", gar=gar,
                               attack="omniscient_linf", n_honest=30, f=9,
                               steps=steps, eta0=eta0,
                               attack_kwargs=(("gar_name", base),) + linf)
            emit(f"fig4/{gar}_attacked_eta{eta0}", r["us_per_step"],
                 f"mean_acc={r['mean_acc']:.3f};to90={r['steps_to_90']};"
                 f"byz_w={r['mean_byz_weight']:.2f};"
                 f"ref_mean={ref['mean_acc']:.3f};"
                 f"ref_to90={ref['steps_to_90']}")

    # fig5-style: lp 'top' variant — single-coordinate sabotage
    lp = (("gamma", "closed"), ("coord", "top"), ("margin", 0.8))
    for gar in ("krum", "bulyan-krum"):
        base = gar.replace("bulyan-", "")
        r = run_experiment(kind="mnist", gar=gar, attack="omniscient_lp",
                           n_honest=30, f=9, steps=steps, eta0=0.3,
                           attack_kwargs=(("gar_name", base),) + lp)
        emit(f"fig5/{gar}_lp", r["us_per_step"],
             f"mean_acc={r['mean_acc']:.3f};"
             f"max_dev={r['max_agg_dev']:.2f};"
             f"byz_w={r['mean_byz_weight']:.2f}")


if __name__ == "__main__":
    main()
