"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by repro.launch.sweep) and emits
one row per (arch x shape x mesh): the three terms, the dominant one, and
the MODEL_FLOPS / HLO_FLOPS utilization ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def rows(art_dir: str = ART):
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        out.append(rec)
    return out


def main(art_dir: str = ART) -> None:
    n_ok = n_skip = n_err = 0
    for rec in rows(art_dir):
        tag = f"{rec.get('arch')}.{rec.get('shape')}" + (
            ".pod2" if rec.get("multi_pod") else ".pod1")
        if rec.get("skipped"):
            n_skip += 1
            emit(f"roofline/{tag}", 0, "skipped(n/a)")
            continue
        if "error" in rec:
            n_err += 1
            emit(f"roofline/{tag}", 0, "ERROR")
            continue
        n_ok += 1
        r = rec["roofline"]
        step_time = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{tag}", 1e6 * step_time,
             f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
             f"collective={r['collective_s']:.4f}s;"
             f"dominant={r['dominant'].replace('_s','')};"
             f"useful_ratio={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}")
    emit("roofline/summary", 0, f"ok={n_ok};skipped={n_skip};errors={n_err}")


if __name__ == "__main__":
    main()
