"""Roofline table: dry-run artifacts + aggregation-backend byte models.

Two row families:

* ``roofline/<arch>.<shape>.<pod>`` — the historic rows read from
  artifacts/dryrun/*.json (produced by repro.launch.sweep): the three
  roofline terms, the dominant one, and the MODEL_FLOPS / HLO_FLOPS
  utilization ratio (EXPERIMENTS.md §Roofline).

* ``roofline/agg.n{n}.f{f}.d{d}`` — the aggregation hot path at the
  paper's production committee (n = 39 = 4f + 3, Fig 4-6) per distance
  backend, from *itemized HBM-byte models* (every term printed in the
  derived column, so the claimed step-times are auditable):

    xla     tensordot distances + gathered (theta, d) sort / cumsum /
            window phase — every intermediate round-trips HBM;
    pallas  kernel pair: tiled Gram + fused coordinate kernel; the
            (theta, d) gather still materializes between them;
    fused   the megakernel (``repro.kernels.fused_agg``): two input
            sweeps, one (d,) write — nothing else touches HBM.

  Step-time = max(bytes / HBM_BW, flops / PEAK) on v5e constants; all
  three backends are memory-bound at production d, so the byte ratio is
  the speedup.  Wall-clock rows are measured only on TPU — off-TPU the
  Pallas kernels run in the pure-Python interpreter, so the rows emit
  ``skipped=interpret-mode-cpu`` (same convention as gar_throughput).

CLI: ``python -m benchmarks.roofline [--quick]`` — ``--quick`` keeps the
smallest d and skips the wall-clock attempts (the CI smoke invocation).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional, Sequence

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# v5e per-chip peaks (same constants as repro.launch.dryrun's roofline)
PEAK_FLOPS = 197e12   # bf16 MXU
HBM_BW = 819e9        # bytes/s

#: production aggregation shape: the paper's Fig 4-6 committee
AGG_N, AGG_F = 39, 9

BF16, F32 = 2, 4


def _agg_bytes(backend: str, n: int, f: int, d: int) -> Dict[str, float]:
    """Itemized HBM traffic (bytes) of one bulyan-krum aggregation.

    Inputs stream bf16 (the production HBM format), intermediates that
    round-trip HBM are fp32 (the accumulation contract), n-sized terms
    (the (n, n) matrix, scores) are dropped as O(n^2) << O(n d).
    """
    theta = n - 2 * f
    if backend == "fused":
        return {
            # phase 0 (distance sweep) + phase 1 (combine) each re-read
            # the full worker stack; selection runs on VMEM residents
            "read_grads_2sweeps": 2 * n * d * BF16,
            "write_agg": d * F32,
        }
    if backend == "pallas":
        return {
            "gram_read_grads": n * d * BF16,
            "gather_read_theta": theta * d * BF16,
            "gather_write_f32": theta * d * F32,
            "select_read_stack": theta * d * F32,
            "write_agg": d * F32,
        }
    if backend == "xla":
        beta = theta - 2 * f
        n_win = theta - beta + 1
        return {
            "dist_read_grads": n * d * BF16,
            "gather_read_theta": theta * d * BF16,
            "gather_write_f32": theta * d * F32,
            "sort_read+write": 2 * theta * d * F32,
            "cumsum_dev_read+write": 2 * (theta + 1) * d * F32,
            "cumsum_val_read+write": 2 * (theta + 1) * d * F32,
            "window_read_prefix": 2 * n_win * d * F32,
            "write_agg": d * F32,
        }
    raise KeyError(f"unknown backend {backend!r}")


def _agg_flops(n: int, d: int) -> float:
    """MXU flops of the Gram contraction (the only matmul-shaped term);
    the VPU sort/window work is bandwidth-limited by construction."""
    return 2.0 * n * n * d


def rows(art_dir: str = ART):
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        out.append(rec)
    return out


def main_artifacts(art_dir: str = ART) -> None:
    """The historic dry-run artifact rows (unchanged format)."""
    n_ok = n_skip = n_err = 0
    for rec in rows(art_dir):
        tag = f"{rec.get('arch')}.{rec.get('shape')}" + (
            ".pod2" if rec.get("multi_pod") else ".pod1")
        if rec.get("skipped"):
            n_skip += 1
            emit(f"roofline/{tag}", 0, "skipped(n/a)")
            continue
        if "error" in rec:
            n_err += 1
            emit(f"roofline/{tag}", 0, "ERROR")
            continue
        n_ok += 1
        r = rec["roofline"]
        step_time = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{tag}", 1e6 * step_time,
             f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
             f"collective={r['collective_s']:.4f}s;"
             f"dominant={r['dominant'].replace('_s','')};"
             f"useful_ratio={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}")
    emit("roofline/summary", 0, f"ok={n_ok};skipped={n_skip};errors={n_err}")


def main_agg_backends(ds: Sequence[int] = (1_000_000, 100_000_000),
                      measure: bool = True) -> None:
    """Aggregation-backend roofline rows at the production (n, f).

    Args:
      ds: coordinate counts to model (production models are the large
        end; the small end sanity-checks against the measured rows).
      measure: attempt wall-clock rows (TPU-only; off-TPU they emit
        ``skipped=interpret-mode-cpu``).
    """
    import jax

    n, f = AGG_N, AGG_F
    for d in ds:
        ref_us: Dict[str, float] = {}
        for backend in ("xla", "pallas", "fused"):
            items = _agg_bytes(backend, n, f, d)
            total = sum(items.values())
            mem_s = total / HBM_BW
            comp_s = _agg_flops(n, d) / PEAK_FLOPS
            us = 1e6 * max(mem_s, comp_s)
            ref_us[backend] = us
            itemized = ";".join(f"{k}={v / d:.0f}d" for k, v in
                                sorted(items.items()))
            speed = (f";speedup_vs_xla={ref_us['xla'] / us:.2f}"
                     if backend != "xla" else "")
            emit(f"roofline/agg.n{n}.f{f}.d{d}", us,
                 f"bytes_total={total / d:.0f}d;{itemized};"
                 f"bound={'mem' if mem_s >= comp_s else 'mxu'}{speed}",
                 backend)
        if not measure:
            continue
        on_tpu = jax.default_backend() == "tpu"
        if not on_tpu:
            for backend in ("xla", "pallas", "fused"):
                emit(f"roofline/agg.n{n}.f{f}.d{d}.measured", 0,
                     "skipped=interpret-mode-cpu", backend)
            continue
        import time

        import jax.numpy as jnp
        from repro.dist.robust import distributed_aggregate
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, d),
                                    jnp.bfloat16)}
        for backend in ("xla", "pallas", "fused"):
            fn = jax.jit(lambda t, b=backend: distributed_aggregate(
                t, f, "bulyan-krum", distance_backend=b)[0])
            jax.block_until_ready(fn(g))          # compile
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                out = fn(g)
            jax.block_until_ready(out)
            us = 1e6 * (time.perf_counter() - t0) / reps
            emit(f"roofline/agg.n{n}.f{f}.d{d}.measured", us,
                 f"model_us={ref_us[backend]:.0f}", backend)


def main(argv: Optional[Sequence[str]] = None) -> None:
    """CLI entry: artifact rows + aggregation-backend rows.

    Args:
      argv: command-line arguments (``None`` = ``sys.argv[1:]``);
        ``--quick`` keeps the smallest modeled d and skips wall-clock
        measurement (the CI smoke run).
    """
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="smallest d only, no wall-clock attempts")
    args = ap.parse_args(argv)
    main_artifacts()
    if args.quick:
        main_agg_backends(ds=(1_000_000,), measure=False)
    else:
        main_agg_backends()


if __name__ == "__main__":
    main()
