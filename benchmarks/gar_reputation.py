"""Arbitrary-f accuracy: reputation-weighted aggregation vs the quorum
family under an anti-aligned colluding majority.

The paper's quorum arithmetic caps every rule's tolerable f — Krum at
``n >= 2f + 3``, Bulyan at ``n >= 4f + 3`` — so at a fixed committee of
``n_total`` workers the quorum family simply *refuses to run* once f
crosses its bound.  ``reputation-<base>`` (ByGARS-style, see
``repro.agg.reputation``) has a quorum constant in f: it runs at any
attacker fraction and defends by down-weighting workers whose
submissions disagree with a clean auxiliary-batch gradient
(``ByzantineSpec(aux_batch=...)`` — agreement with the emitted aggregate
alone bootstraps wrong once the colluders own the aggregate).

Rows: ``gar_reputation/<rule>_f<k>`` at f in {n/4, n/2, 3n/4} of
``n_total = 12`` workers under the ``colluding_majority`` attack with
``direction="anti"``, plus the clean ``average`` baseline.  The attack
is *norm-bounded* — the paper's own hidden-vulnerability regime: the
tight anti-aligned cluster wins Krum's selection outright at Krum's own
admissible f (the ``krum_f3`` row collapses) while a coordinate mean
barely moves (``average`` rides it out — but carries no Byzantine
guarantee once the bound is lifted), and ``reputation-krum`` repairs
the selection at every f.  Quorum-refused combinations emit a
``refused=quorum`` row instead of an accuracy — the refusal is the
datum.  The derived column carries ``acc`` and ``clean_frac`` (accuracy
as a fraction of the clean baseline); the ISSUE 9 acceptance bar is
``clean_frac >= 0.9`` for ``reputation-krum`` at f = n/2.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_eval, mnist_loss
from repro.data import ByzantineBatcher
from repro.data.synthetic import mnist_like
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import ByzantineSpec, ByzantineTrainer

N_TOTAL = 12


def _aux_batch(seed: int = 123, batch: int = 64, noise: float = 0.5):
    """One clean MNIST batch: the trusted scoring signal of ByGARS."""
    x, y = mnist_like(batch, 10 ** 6, seed=seed, noise=noise)
    return jnp.asarray(x), jnp.asarray(y)


def _train(gar: str, f: int, steps: int, seed: int = 1):
    """Train one (rule, f) cell; returns (us_per_step, accuracy)."""
    reputed = gar.startswith("reputation-")
    n_honest = N_TOTAL - f
    spec = ByzantineSpec(
        n_workers=N_TOTAL if f else n_honest, f=f, gar=gar,
        attack="colluding_majority" if f else "none", seed=seed,
        # eps is in delta_bar units along a *unit* direction, and this
        # easy task's honest workers agree to ~3 decimal places
        # (delta_bar ~ 0.006 vs a mean-gradient norm ~ 1.75): 300 puts
        # the cluster a full gradient-norm anti-aligned — norm-bounded
        # enough to look plausible, tight enough to win krum's selection
        attack_kwargs=(("direction", "anti"), ("eps", 300.0)) if f else (),
        rep_lr=0.9 if reputed else None,
        aux_batch=_aux_batch() if reputed else None)
    tr = ByzantineTrainer(
        mnist_loss, simple.init_mnist_mlp(jax.random.PRNGKey(seed)),
        # eta0 = 0.3 (the fig2 setting): the 12-worker committee's
        # aggregate is noisier than the 30-worker benches', and 1.0
        # diverges even clean
        get_optimizer("sgd", fading_lr(0.3, 10000)), spec)
    batcher = ByzantineBatcher("mnist", n_honest, 32, seed=seed, noise=0.5)
    tr.run(batcher, 3)                      # compile + warm the carry
    t0 = time.time()
    tr.run(batcher, steps, start_step=3)
    wall = time.time() - t0
    acc = float(make_eval("mnist")(tr.params))
    return 1e6 * wall / steps, acc


def main(steps: int = 40, seed: int = 1) -> None:
    """One row per (rule, f) on the fixed-committee MNIST protocol.

    Args:
      steps: measured training steps per cell (after a 3-step warmup).
      seed: PRNG seed threaded to init, batching and the attack — the
        accuracy columns are deterministic per seed.

    Returns:
      None (emits CSV rows).
    """
    us0, clean = _train("average", 0, steps, seed=seed)
    emit("gar_reputation/average_clean", us0, f"acc={clean:.3f}")
    fs = (N_TOTAL // 4, N_TOTAL // 2, 3 * N_TOTAL // 4)
    # krum is the defeated baseline: the tight cluster wins its selection
    # at krum's own admissible f=3, and it refuses past the quorum (as
    # does bulyan everywhere here); average rides the bounded offset out
    # but has no guarantee; reputation-krum holds at every f
    for gar in ("average", "krum", "bulyan-krum", "reputation-krum"):
        for f in fs:
            try:
                ByzantineSpec(n_workers=N_TOTAL, f=f, gar=gar).validate()
            except ValueError:
                # the quorum family cannot even run here — the refusal
                # is the row (reputation-* must never land in it)
                emit(f"gar_reputation/{gar}_f{f}", 0.0, "refused=quorum")
                continue
            us, acc = _train(gar, f, steps, seed=seed)
            emit(f"gar_reputation/{gar}_f{f}", us,
                 f"acc={acc:.3f};clean_frac={acc / max(clean, 1e-9):.3f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short runs (the CI smoke setting)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()
    print("name,backend,us_per_call,derived")
    main(steps=120 if args.full else 40, seed=args.seed)
