"""Benchmark harness: one module per paper table/figure plus the systems
benches.  Prints ``name,backend,us_per_call,derived`` CSV rows — the
``backend`` column tags distance-backend comparison rows (xla/pallas)
and the sync-vs-async runtime rows of ``gar_async`` (sync/async);
``-`` marks backend-independent benches.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full] [--only NAME]

Alongside the CSV stream, every bench writes a reproducibility artifact
``benchmarks/artifacts/BENCH_<name>.json`` carrying its parsed rows plus
the environment (jax version, backend, device/host counts, python) and
the effective seed — enough to pin down *which* machine and RNG stream
produced a row when two runs disagree.  ``--no-artifacts`` disables the
writes (e.g. on read-only checkouts).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import pathlib
import platform
import sys
import time

ARTIFACTS = pathlib.Path(__file__).resolve().parent / "artifacts"


def bench_env() -> dict:
    """Environment fingerprint stamped into every ``BENCH_*.json``."""
    import jax
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "devices": str(jax.devices()[0]),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def _parse_rows(text: str) -> list:
    """CSV-looking ``name,backend,us,derived`` lines -> row dicts."""
    rows = []
    for line in text.splitlines():
        parts = line.split(",", 3)
        if len(parts) != 4 or " " in parts[0]:
            continue
        name, backend, us, derived = parts
        try:
            us_val = float(us)
        except ValueError:
            continue
        rows.append({"name": name, "backend": backend,
                     "us_per_call": us_val, "derived": derived})
    return rows


def write_artifact(name: str, rows: list, *, seed, env: dict,
                   wall_s: float, extra: dict = None) -> pathlib.Path:
    """Write ``BENCH_<name>.json`` and return its path."""
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / f"BENCH_{name}.json"
    doc = {"bench": name, "seed": seed, "wall_s": round(wall_s, 3),
           "env": env, "rows": rows}
    if extra:
        doc.update(extra)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training runs (closer to the paper's "
                         "epoch counts)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="override the default PRNG seed of the benches "
                         "that thread one (leeway, gar_async) — rows "
                         "become a pure function of the seed")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="skip the BENCH_<name>.json artifact writes")
    args = ap.parse_args()

    from benchmarks import (fig2_mnist_attack, fig3_cifar_attack,
                            fig45_bulyan_defense, fig6_bulyan_cost,
                            gar_async, gar_reputation, gar_throughput,
                            leeway_scaling, obs_overhead, roofline,
                            serve_robust)

    steps2 = 400 if args.full else 120
    steps3 = 200 if args.full else 50
    steps45 = 400 if args.full else 120
    steps6 = 150 if args.full else 60
    steps_async = 120 if args.full else 60
    steps_rep = 120 if args.full else 40
    seeded = {} if args.seed is None else {"seed": args.seed}

    benches = [
        ("leeway", lambda: leeway_scaling.main(**seeded)),
        ("gar_throughput", lambda: gar_throughput.main()),
        ("gar_throughput_dist", lambda: gar_throughput.main_dist()),
        ("gar_backends", lambda: gar_throughput.main_backends()),
        ("gar_buffered", lambda: gar_throughput.main_buffered()),
        ("gar_async", lambda: gar_async.main(steps=steps_async,
                                             **seeded)),
        ("gar_reputation", lambda: gar_reputation.main(steps=steps_rep,
                                                       **seeded)),
        ("serve_robust", lambda: serve_robust.main()),
        ("serve_speculative", lambda: serve_robust.main_speculative()),
        ("obs_overhead", lambda: obs_overhead.main()),
        ("fig2", lambda: fig2_mnist_attack.main(steps=steps2)),
        ("fig3", lambda: fig3_cifar_attack.main(steps=steps3)),
        ("fig45", lambda: fig45_bulyan_defense.main(steps=steps45)),
        ("fig6", lambda: fig6_bulyan_cost.main(steps=steps6)),
        ("roofline", lambda: roofline.main()),
    ]
    env = bench_env()
    print("name,backend,us_per_call,derived")
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        buf = io.StringIO()
        err = None
        # tee: rows stream to the terminal unchanged AND get captured
        # for the JSON artifact
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception as e:  # keep the harness going
            err = f"{type(e).__name__}:{e}"
        captured = buf.getvalue()
        sys.stdout.write(captured)
        if err:
            print(f"{name}/ERROR,-,0,{err}", flush=True)
        wall = time.time() - t0
        print(f"{name}/total,-,{1e6 * wall:.0f},done", flush=True)
        if not args.no_artifacts:
            rows = _parse_rows(captured)
            extra = {"error": err} if err else None
            write_artifact(name, rows, seed=args.seed, env=env,
                           wall_s=wall, extra=extra)


if __name__ == "__main__":
    main()
