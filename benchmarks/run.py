"""Benchmark harness: one module per paper table/figure plus the systems
benches.  Prints ``name,backend,us_per_call,derived`` CSV rows — the
``backend`` column tags distance-backend comparison rows (xla/pallas)
and the sync-vs-async runtime rows of ``gar_async`` (sync/async);
``-`` marks backend-independent benches.

    PYTHONPATH=src python -m benchmarks.run [--quick|--full] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training runs (closer to the paper's "
                         "epoch counts)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="override the default PRNG seed of the benches "
                         "that thread one (leeway, gar_async) — rows "
                         "become a pure function of the seed")
    args = ap.parse_args()

    from benchmarks import (fig2_mnist_attack, fig3_cifar_attack,
                            fig45_bulyan_defense, fig6_bulyan_cost,
                            gar_async, gar_reputation, gar_throughput,
                            leeway_scaling, roofline, serve_robust)

    steps2 = 400 if args.full else 120
    steps3 = 200 if args.full else 50
    steps45 = 400 if args.full else 120
    steps6 = 150 if args.full else 60
    steps_async = 120 if args.full else 60
    steps_rep = 120 if args.full else 40
    seeded = {} if args.seed is None else {"seed": args.seed}

    benches = [
        ("leeway", lambda: leeway_scaling.main(**seeded)),
        ("gar_throughput", lambda: gar_throughput.main()),
        ("gar_throughput_dist", lambda: gar_throughput.main_dist()),
        ("gar_backends", lambda: gar_throughput.main_backends()),
        ("gar_buffered", lambda: gar_throughput.main_buffered()),
        ("gar_async", lambda: gar_async.main(steps=steps_async,
                                             **seeded)),
        ("gar_reputation", lambda: gar_reputation.main(steps=steps_rep,
                                                       **seeded)),
        ("serve_robust", lambda: serve_robust.main()),
        ("serve_speculative", lambda: serve_robust.main_speculative()),
        ("fig2", lambda: fig2_mnist_attack.main(steps=steps2)),
        ("fig3", lambda: fig3_cifar_attack.main(steps=steps3)),
        ("fig45", lambda: fig45_bulyan_defense.main(steps=steps45)),
        ("fig6", lambda: fig6_bulyan_cost.main(steps=steps6)),
        ("roofline", lambda: roofline.main()),
    ]
    print("name,backend,us_per_call,derived")
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the harness going
            print(f"{name}/ERROR,-,0,{type(e).__name__}:{e}", flush=True)
        print(f"{name}/total,-,{1e6 * (time.time() - t0):.0f},done",
              flush=True)


if __name__ == "__main__":
    main()
