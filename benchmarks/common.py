"""Shared helpers for the paper-figure benchmarks.

Worker counts are the paper's own (§5.2):
  Fig 2/3:  Krum/GeoMed 30 honest + 27 Byzantine (n = 2f+3 minimal quorum),
            Brute 6 + 5, Average 30 + 0 (the clean reference).
  Fig 4/5:  30 honest + 9 Byzantine (n = 39 = 4f+3, Bulyan's minimal quorum).
  Fig 6:    n = 39 workers, no adversary, f declared 9.

The omniscient attack uses the paper's §B closed-form gamma estimate (the
"linear regression" shortcut) with a safety margin; the per-step
``byz_weight`` metric verifies the submission is actually selected.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import ByzantineBatcher
from repro.data.synthetic import cifar_like, mnist_like
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import ByzantineSpec, ByzantineTrainer


def mnist_loss(params, x, y):
    return simple.classification_loss(
        simple.mnist_mlp_forward(params, x), y, params)


def cifar_loss(params, x, y):
    return simple.classification_loss(
        simple.cifar_cnn_forward(params, x), y, params)


def make_eval(kind: str, n: int = 1000, noise: float = 0.5):
    if kind == "mnist":
        xe, ye = mnist_like(n, 10 ** 6, seed=0, noise=noise)
        fwd = simple.mnist_mlp_forward
    else:
        xe, ye = cifar_like(n, 10 ** 6, seed=0, noise=noise)
        fwd = simple.cifar_cnn_forward
    xe, ye = jnp.asarray(xe), jnp.asarray(ye)

    def eval_fn(params):
        return simple.accuracy(fwd(params, xe), ye)

    return eval_fn


def run_experiment(*, kind: str, gar: str, attack: str, n_honest: int,
                   f: int, steps: int, batch: int = 16, eta0: float = 0.3,
                   r_eta: float = 10000.0, attack_until: Optional[int] = None,
                   attack_kwargs: tuple = (), eval_every: int = 5,
                   noise: float = 0.5, seed: int = 1) -> Dict:
    key = jax.random.PRNGKey(seed)
    if kind == "mnist":
        params = simple.init_mnist_mlp(key)
        loss = mnist_loss
    else:
        params = simple.init_cifar_cnn(key)
        loss = cifar_loss
    spec = ByzantineSpec(n_workers=n_honest + f, f=f, gar=gar,
                         attack=attack, attack_kwargs=attack_kwargs)
    opt = get_optimizer("sgd", fading_lr(eta0, r_eta))
    trainer = ByzantineTrainer(loss, params, opt, spec, seed=seed)
    eval_fn = make_eval(kind, noise=noise)
    t0 = time.time()
    trainer.run(ByzantineBatcher(kind, n_honest, batch, seed=seed,
                                 noise=noise), steps,
                attack_until=attack_until, eval_fn=eval_fn,
                eval_every=eval_every)
    wall = time.time() - t0
    accs = [(h["step"], h["eval_acc"]) for h in trainer.history
            if "eval_acc" in h]
    acc_vals = [a for _, a in accs]
    to90 = next((s for s, a in accs if a >= 0.9), None)
    return {
        "final_acc": float(eval_fn(trainer.params)),
        "accs": accs,
        "mean_acc": float(np.mean(acc_vals)) if acc_vals else 0.0,
        "steps_to_90": to90,
        "us_per_step": 1e6 * wall / steps,
        "mean_byz_weight": float(np.mean(
            [h["byz_weight"] for h in trainer.history])),
        "max_agg_dev": float(np.max(
            [h["agg_dev"] for h in trainer.history])),
        "history": trainer.history,
    }


def emit(name: str, us: float, derived: str, backend: str = "-") -> None:
    """One CSV row: ``name,backend,us_per_call,derived``.

    ``backend`` tags rows produced under a specific distance backend
    (``xla`` / ``pallas``); ``"-"`` marks backend-independent rows.
    """
    print(f"{name},{backend},{us:.0f},{derived}", flush=True)
