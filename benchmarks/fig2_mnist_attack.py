"""Paper Fig. 2 (+ §C.1 Fig. 7): MNIST accuracy under the omniscient
attack, per GAR, with the paper's worker counts (Krum/GeoMed 30+27 minimal
quorum, Brute 6+5, Average 30+0 clean reference).

Reproduction note (EXPERIMENTS.md §Fidelity): the offline synthetic task
is near-convex with Bayes accuracy 1.0, so the paper's *lasting* collapse
(which relies on real-MNIST non-convex basins) cannot appear; what
reproduces is the attack's *convergence damage* — mean accuracy over the
run and steps-to-90% degrade for Krum/GeoMed under attack while the clean
reference and Brute stay fast.  Both the lp (one-coordinate, main paper)
and linf ("anti" direction, §C.1 — the stronger variant) attacks run.
"""
from __future__ import annotations

from benchmarks.common import emit, run_experiment


def _fmt(r, ref):
    return (f"mean_acc={r['mean_acc']:.3f};final={r['final_acc']:.3f};"
            f"to90={r['steps_to_90']};byz_w={r['mean_byz_weight']:.2f};"
            f"ref_mean={ref['mean_acc']:.3f};ref_to90={ref['steps_to_90']}")


def main(steps: int = 120) -> None:
    ref = run_experiment(kind="mnist", gar="average", attack="none",
                         n_honest=30, f=0, steps=steps)
    emit("fig2/average_clean", ref["us_per_step"],
         f"mean_acc={ref['mean_acc']:.3f};final={ref['final_acc']:.3f};"
         f"to90={ref['steps_to_90']}")

    lp = (("gamma", "closed"), ("coord", "top"), ("margin", 0.8))
    linf = (("gamma", "closed"), ("direction", "anti"), ("margin", 0.8))
    for gar, nh, f in [("krum", 30, 27), ("geomed", 30, 27),
                       ("brute", 6, 5)]:
        for aname, akw in [("lp", lp), ("linf", linf)]:
            r = run_experiment(kind="mnist", gar=gar,
                               attack=f"omniscient_{aname}",
                               n_honest=nh, f=f, steps=steps,
                               attack_kwargs=(("gar_name", gar),) + akw)
            emit(f"fig2/{gar}_{aname}", r["us_per_step"], _fmt(r, ref))


if __name__ == "__main__":
    main()
