"""Paper Fig. 3: CIFAR-10 (CNN, ~1e6 params) under the maintained attack.
Worker counts per paper: Krum/GeoMed 21+18, Brute 6+5, Average 21+0.
See fig2 module docstring for the fidelity note."""
from __future__ import annotations

from benchmarks.common import emit, run_experiment


def main(steps: int = 50) -> None:
    ref = run_experiment(kind="cifar", gar="average", attack="none",
                         n_honest=21, f=0, steps=steps, eta0=0.1,
                         r_eta=2000)
    emit("fig3/average_clean", ref["us_per_step"],
         f"mean_acc={ref['mean_acc']:.3f};final={ref['final_acc']:.3f}")

    linf = (("gamma", "closed"), ("direction", "anti"), ("margin", 0.8))
    for gar, nh, f in [("krum", 21, 18), ("geomed", 21, 18),
                       ("brute", 6, 5)]:
        r = run_experiment(kind="cifar", gar=gar, attack="omniscient_linf",
                           n_honest=nh, f=f, steps=steps, eta0=0.1,
                           r_eta=2000,
                           attack_kwargs=(("gar_name", gar),) + linf)
        emit(f"fig3/{gar}_linf", r["us_per_step"],
             f"mean_acc={r['mean_acc']:.3f};final={r['final_acc']:.3f};"
             f"byz_w={r['mean_byz_weight']:.2f};"
             f"ref_mean={ref['mean_acc']:.3f}")


if __name__ == "__main__":
    main()
