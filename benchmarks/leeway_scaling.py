"""Scaling validation of the paper's two analytic results.

1. §3.2 / §B: the attacker's selected margin gamma_m grows like
   Omega(sqrt(d)) for Krum/GeoMed (p = 2).  We measure gamma_m by the exact
   growth+bisection search at several d and fit the log-log slope —
   expected ~ 0.5.

2. Proposition 2: Bulyan's per-coordinate deviation from the honest mean
   under the *same* attack stays O(sigma_coord) = O(sigma / sqrt(d)) of
   the full-gradient sigma — i.e. flat in d on a per-coordinate scale
   while Krum's grows like sqrt(d): the ratio Krum/Bulyan grows ~ sqrt(d).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (find_gamma_max, get_attack, get_gar,
                        make_selection_checker)


def main(dims=(64, 256, 1024, 4096), n_h: int = 12, f: int = 3) -> None:
    key = jax.random.PRNGKey(11)
    gammas = {"krum": [], "geomed": []}
    ratios = []
    for d in dims:
        honest = jax.random.normal(jax.random.fold_in(key, d),
                                   (n_h, d)) * 0.5 + 1.0
        e = jnp.zeros((d,)).at[0].set(1.0)
        t0 = time.time()
        for rule in ("krum", "geomed"):
            check = make_selection_checker(rule, f)
            g = float(find_gamma_max(honest, f, e, check))
            gammas[rule].append(g)
        # attack tuned against krum; measure aggregate deviation
        byz = get_attack("omniscient_lp")(honest, f, None, gar_name="krum",
                                          margin=0.95)
        full = jnp.concatenate([honest, byz])
        mean = jnp.mean(honest, axis=0)
        kdev = float(jnp.max(jnp.abs(
            get_gar("krum")(full, f).gradient - mean)))
        bdev = float(jnp.max(jnp.abs(
            get_gar("bulyan-krum")(full, f).gradient - mean)))
        ratios.append(kdev / max(bdev, 1e-9))
        us = 1e6 * (time.time() - t0)
        emit(f"leeway/d{d}", us,
             f"gamma_krum={gammas['krum'][-1]:.2f};"
             f"gamma_geomed={gammas['geomed'][-1]:.2f};"
             f"krum_dev={kdev:.2f};bulyan_dev={bdev:.3f};"
             f"ratio={ratios[-1]:.1f}")

    ld = np.log(np.asarray(dims, float))
    for rule in ("krum", "geomed"):
        slope = np.polyfit(ld, np.log(np.asarray(gammas[rule])), 1)[0]
        emit(f"leeway/slope_{rule}", 0,
             f"loglog_slope={slope:.3f};expected~0.5")
    rslope = np.polyfit(ld, np.log(np.asarray(ratios)), 1)[0]
    emit("leeway/slope_krum_over_bulyan", 0,
         f"loglog_slope={rslope:.3f};expected~0.5(Prop2)")


if __name__ == "__main__":
    main()
