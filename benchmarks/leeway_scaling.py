"""Scaling validation of the paper's two analytic results.

1. §3.2 / §B: the attacker's selected margin gamma_m grows like
   Omega(sqrt(d)) for Krum/GeoMed (p = 2).  We measure gamma_m by the exact
   growth+bisection search at several d and fit the log-log slope —
   expected ~ 0.5.

2. Proposition 2: Bulyan's per-coordinate deviation from the honest mean
   under the *same* attack stays O(sigma_coord) = O(sigma / sqrt(d)) of
   the full-gradient sigma — i.e. flat in d on a per-coordinate scale
   while Krum's grows like sqrt(d): the ratio Krum/Bulyan grows ~ sqrt(d).

The measurement itself lives in ``repro.audit.leeway`` (the adversarial
self-audit's leeway meter, which also certifies the slopes against the
checked-in ``benchmarks/artifacts/leeway_baseline.json``); this bench
renders the same deterministic report as CSV rows and can re-emit the
JSON artifact via ``--out``.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import emit
from repro.audit.leeway import measure_leeway


def main(dims=(64, 256, 1024, 4096), n_h: int = 12, f: int = 3,
         seed: int = 11, out: str = "") -> None:
    """Emit the leeway-scaling CSV rows (and optionally the artifact).

    Args:
      dims: dimension ladder.
      n_h: honest worker count.
      f: Byzantine worker count.
      seed: PRNG seed — rows are a pure function of the arguments.
      out: when non-empty, also write the JSON artifact here (the file
        CI's leeway gate regresses against).

    Returns:
      None (emits CSV rows).
    """
    t0 = time.time()
    report = measure_leeway(
        rules=("average", "krum", "geomed", "bulyan-krum"),
        dims=dims, n_h=n_h, f=f, seed=seed)
    us = 1e6 * (time.time() - t0) / max(len(dims), 1)
    rules = report["rules"]
    gamma = report["gamma"]
    for i, d in enumerate(dims):
        kdev = rules["krum"]["margin_abs"][i]
        bdev = rules["bulyan-krum"]["margin_abs"][i]
        emit(f"leeway/d{d}", us,
             f"gamma_krum={gamma['krum']['values'][i]:.2f};"
             f"gamma_geomed={gamma['geomed']['values'][i]:.2f};"
             f"krum_dev={kdev:.2f};bulyan_dev={bdev:.3f};"
             f"ratio={kdev / max(bdev, 1e-9):.1f}")
    for rule in ("krum", "geomed"):
        emit(f"leeway/slope_{rule}", 0,
             f"loglog_slope={gamma[rule]['slope']:.3f};expected~0.5")
    rslope = (rules["krum"]["slope_abs"]
              - rules["bulyan-krum"]["slope_abs"])
    emit("leeway/slope_krum_over_bulyan", 0,
         f"loglog_slope={rslope:.3f};expected~0.5(Prop2)")
    if out:
        with open(out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--out", type=str, default="")
    args = ap.parse_args()
    main(seed=args.seed, out=args.out)
