"""Telemetry overhead: the forensics ring must cost < 5% of a train step.

Times the jitted flat Byzantine train step with ``telemetry=False`` and
``telemetry=True`` on identical data for several defended GARs, and
emits one ``obs/overhead_<gar>`` row per rule plus the headline
``obs/overhead`` row whose ``derived`` column carries the worst-case
ratio — the acceptance gate the CI fast job greps for.

The instrumented step is the *same computation* plus the in-graph
diagnostics (per-worker distances, selection mask, ring write), so the
ratio measures exactly what ``obs-*`` composites add.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, mnist_loss
from repro.models import simple
from repro.optim import get_optimizer
from repro.training import ByzantineSpec
from repro.training.trainer import (init_flat_agg_state,
                                    make_byzantine_step)


def _make_timer(spec: ByzantineSpec, params, opt, x, y, reps: int):
    """Compile the flat step for ``spec``; return a us/call sampler."""
    step = jax.jit(make_byzantine_step(mnist_loss, opt, spec,
                                       attack_on=spec.attack != "none"))
    key = jax.random.PRNGKey(0)
    state = init_flat_agg_state(spec, params)
    opt_state = opt.init(params)
    stateful = spec.rule().stateful

    def call(p, o, s):
        if stateful:
            return step(p, o, x, y, key, s)
        return step(p, o, x, y, key) + (s,)

    out = call(params, opt_state, state)  # compile
    jax.block_until_ready(out)

    def sample() -> float:
        p, o, s = params, opt_state, state
        t0 = time.perf_counter()
        for _ in range(reps):
            out = call(p, o, s)
            p, o = out[0], out[1]
            s = out[-1] if stateful else s
        jax.block_until_ready(out)
        return 1e6 * (time.perf_counter() - t0) / reps

    return sample


def main(gars=("krum", "cwmed", "bulyan-krum"), n_workers: int = 15,
         f: int = 3, batch: int = 64, reps: int = 15,
         rounds: int = 5) -> None:
    """Emit the off/on/ratio rows for each defended GAR.

    Off and on are sampled in **interleaved rounds** (off, on, off, on,
    ...) and each side takes its best round, so slow machine-load drift
    cancels instead of landing entirely on one side of the ratio.

    Args:
      gars: base rule names to instrument (each must satisfy its quorum
        at ``(n_workers, f)``).
      n_workers: committee size of the flat protocol.
      f: injected Byzantine rows.
      batch: per-worker batch size.
      reps: timed calls per round (after one compile call).
      rounds: interleaved off/on rounds; each side keeps its minimum.
    """
    from repro.data import ByzantineBatcher

    params = simple.init_mnist_mlp(jax.random.PRNGKey(0))
    worst = 0.0
    for gar in gars:
        spec_off = ByzantineSpec(n_workers=n_workers, f=f, gar=gar,
                                 attack="signflip", telemetry=False)
        spec_on = ByzantineSpec(n_workers=n_workers, f=f, gar=gar,
                                attack="signflip", telemetry=True)
        opt = get_optimizer("sgd", 0.05)
        x, y = ByzantineBatcher("mnist", spec_off.n_honest, batch).batch(0)
        x, y = jnp.asarray(x), jnp.asarray(y)
        sample_off = _make_timer(spec_off, params, opt, x, y, reps)
        sample_on = _make_timer(spec_on, params, opt, x, y, reps)
        off, on = float("inf"), float("inf")
        for _ in range(rounds):
            off = min(off, sample_off())
            on = min(on, sample_on())
        ratio = on / off
        worst = max(worst, ratio)
        emit(f"obs/overhead_{gar}", on - off,
             f"off={off:.0f}us;on={on:.0f}us;ratio={ratio:.3f}")
    emit("obs/overhead", 0,
         f"worst_ratio={worst:.3f};gate=1.05")


if __name__ == "__main__":
    main()
