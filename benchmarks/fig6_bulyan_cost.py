"""Paper Fig. 6: the cost of Bulyan without an adversary — accuracy at a
fixed step vs mini-batch size, Average vs Bulyan(Krum), n = 39 workers,
f declared 9 but zero actual Byzantines.

Expected: Bulyan's convergence-speed loss shrinks to ~nothing at a
reasonable batch size (paper: 24 images/batch for MNIST).
"""
from __future__ import annotations

from benchmarks.common import emit, run_experiment


def main(steps: int = 60) -> None:
    for batch in (4, 12, 24, 48):
        accs = {}
        for gar in ("average", "bulyan-krum"):
            r = run_experiment(kind="mnist", gar=gar, attack="none",
                               n_honest=39, f=0, steps=steps, batch=batch,
                               attack_kwargs=(), eval_every=steps)
            # note: f=0 actual; Bulyan still *declares* f=9 via declared_f
            accs[gar] = r
        # re-run bulyan with declared f=9 (the paper's setting)
        import jax
        from repro.data import ByzantineBatcher
        from repro.models import simple
        from repro.optim import fading_lr, get_optimizer
        from repro.training import ByzantineSpec, ByzantineTrainer
        from benchmarks.common import make_eval, mnist_loss
        spec = ByzantineSpec(n_workers=39, f=0, gar="bulyan-krum",
                             attack="none", declared_f=9)
        tr = ByzantineTrainer(mnist_loss,
                              simple.init_mnist_mlp(jax.random.PRNGKey(1)),
                              get_optimizer("sgd", fading_lr(1.0, 10000)),
                              spec)
        import time
        t0 = time.time()
        tr.run(ByzantineBatcher("mnist", 39, batch, seed=1), steps)
        us = 1e6 * (time.time() - t0) / steps
        acc_b = float(make_eval("mnist")(tr.params))
        emit(f"fig6/batch{batch}", us,
             f"avg={accs['average']['final_acc']:.3f};"
             f"bulyan_f9={acc_b:.3f};"
             f"gap={accs['average']['final_acc'] - acc_b:+.3f}")


if __name__ == "__main__":
    main()
