"""Proposition 1: aggregation cost.  Measures us/call for every GAR across
(n, d) and checks the two analytic claims:

  * Krum/GeoMed/Bulyan are O(n^2 d) — cost ~ linear in d at fixed n;
  * Bulyan(Krum) amortizes distance computation: its cost stays within a
    small factor of plain Krum (paper: same O(n^2 d) up to constants),
    NOT theta times Krum.

``main_dist`` benches the distributed path (``repro.dist.robust``) against
the flat core on the same data: per-leaf Gram accumulation + windowed
coordinate phase vs one flat (n, d) matrix.  On one device the two should
be within a small factor; the distributed form is the one that shards.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.agg import init_state, resolve_rule
from repro.core import get_gar
from repro.core import pytree as pt
from repro.dist.robust import distributed_aggregate


def _time(fn, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / reps


def main(ds=(10_000, 100_000, 1_000_000), ns=(15, 39)) -> None:
    key = jax.random.PRNGKey(0)
    results = {}
    for n in ns:
        f = (n - 3) // 4
        for d in ds:
            g = jax.random.normal(key, (n, d))
            for name in ("average", "cwmed", "trimmed_mean", "krum",
                         "geomed", "multikrum", "bulyan-krum",
                         "centered_clip"):
                gar = get_gar(name)
                jitted = jax.jit(lambda x, gar=gar: gar(x, f).gradient)
                us = _time(jitted, g)
                results[(name, n, d)] = us
                emit(f"gar_throughput/{name}_n{n}_d{d}", us,
                     f"bytes={4 * n * d}")
    # derived checks
    for n in ns:
        k = results[("krum", n, ds[-1])]
        b = results[("bulyan-krum", n, ds[-1])]
        emit(f"gar_throughput/bulyan_over_krum_n{n}", 0,
             f"ratio={b / k:.2f};amortized<<theta={n - 2 * ((n - 3) // 4)}")
        lin = results[("krum", n, ds[-1])] / results[("krum", n, ds[0])]
        emit(f"gar_throughput/krum_d_scaling_n{n}", 0,
             f"t(d*100)/t(d)={lin:.1f};expected~100(O(n^2 d))")


def _stacked_tree(key, n: int, d_total: int):
    """Multi-leaf gradient tree (total d coords) mimicking a real param
    tree: a big matrix leaf, a medium one, and a small vector leaf."""
    k1, k2, k3 = jax.random.split(key, 3)
    d_big = int(d_total * 0.8)
    d_mid = int(d_total * 0.19)
    d_small = d_total - d_big - d_mid
    return {"w_big": jax.random.normal(k1, (n, d_big // 64, 64)),
            "w_mid": jax.random.normal(k2, (n, d_mid)),
            "bias": jax.random.normal(k3, (n, d_small))}


def main_dist(ds=(100_000, 1_000_000), ns=(15, 39)) -> None:
    """Distributed (tree-aware) path vs flat core on identical data."""
    key = jax.random.PRNGKey(1)
    for n in ns:
        f = (n - 3) // 4
        for d in ds:
            tree = _stacked_tree(key, n, d)
            flat, _ = pt.stack_flatten(tree)
            for name in ("krum", "bulyan-krum", "trimmed_mean"):
                gar = get_gar(name)
                flat_fn = jax.jit(lambda x, gar=gar: gar(x, f).gradient)
                # pin xla: these rows measure the tree *decomposition*
                # cost, which must stay backend-stable across hosts
                # (main_backends owns the xla-vs-pallas comparison)
                tree_fn = jax.jit(
                    lambda t, name=name: distributed_aggregate(
                        t, f, name, distance_backend="xla")[0])
                us_flat = _time(flat_fn, flat)
                us_tree = _time(tree_fn, tree)
                emit(f"gar_throughput/dist_{name}_n{n}_d{d}", us_tree,
                     f"flat_us={us_flat:.0f};ratio={us_tree / us_flat:.2f}")


def main_backends(ds=(100_000, 1_000_000), ns=(15, 39)) -> None:
    """xla vs pallas distance backend on the same stacked trees, plus the
    sharded-style tree vs the flat (n, d) matrix per backend.

    Off-TPU the Pallas rows run through the interpreter (the parity
    check, not a perf number — interpret mode is pure-Python per grid
    step); on TPU they are the compiled-kernel measurement.  The
    ``dist_vs_flat`` ratio shows what the tree decomposition costs over
    one flat matmul at each d.
    """
    key = jax.random.PRNGKey(2)
    on_tpu = jax.default_backend() == "tpu"
    for n in ns:
        f = (n - 3) // 4
        for d in ds:
            tree = _stacked_tree(key, n, d)
            flat, _ = pt.stack_flatten(tree)
            flat_gar = get_gar("krum")
            us_flat = _time(jax.jit(lambda x: flat_gar(x, f).gradient),
                            flat)
            for backend in ("xla", "pallas"):
                if backend == "pallas" and not on_tpu and d > ds[0]:
                    emit(f"gar_throughput/backend_krum_n{n}_d{d}", 0,
                         "skipped=interpret-mode-cpu", backend)
                    continue
                fn = jax.jit(lambda t, b=backend: distributed_aggregate(
                    t, f, "krum", distance_backend=b)[0])
                us = _time(fn, tree)
                emit(f"gar_throughput/backend_krum_n{n}_d{d}", us,
                     f"flat_us={us_flat:.0f};"
                     f"dist_vs_flat={us / us_flat:.2f}", backend)


def main_buffered(ds=(100_000, 1_000_000), ns=(15,)) -> None:
    """Stateful rules (buffered-* history window, momentum centered-clip)
    vs their stateless bases on the same data.

    The derived column reports the overhead ratio over the stateless
    base — the cost of the ring-buffer write + window mean (buffered-*)
    or of the carried center (centered_clip_momentum).  Each measured
    call's returned state feeds the next call, exactly as the trainer
    loop threads it.
    """
    key = jax.random.PRNGKey(3)
    pairs = (("buffered-cwmed", "cwmed"), ("buffered-krum", "krum"),
             ("centered_clip_momentum", "centered_clip"))

    def _time_threaded(fn, x, s, reps: int = 5) -> float:
        out, s = fn(x, s)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out, s = fn(x, s)
        jax.block_until_ready(out)
        return 1e6 * (time.time() - t0) / reps

    for n in ns:
        f = (n - 3) // 4
        for d in ds:
            g = jax.random.normal(key, (n, d))
            for name, base in pairs:
                rule = resolve_rule(name)
                base_fn = get_gar(base)
                us_base = _time(
                    jax.jit(lambda x, fn=base_fn: fn(x, f).gradient), g)
                state = init_state(rule, g)

                @jax.jit
                def stateful(x, s, fn=rule.dense_fn):
                    res, s = fn(x, f, s)
                    return res.gradient, s

                # prime the history so the steady-state cost is measured
                _, state = stateful(g, state)
                us = _time_threaded(stateful, g, state)
                emit(f"gar_throughput/{name}_n{n}_d{d}", us,
                     f"base_us={us_base:.0f};"
                     f"stateful_over_base={us / max(us_base, 1e-9):.2f}")


if __name__ == "__main__":
    main()
    main_dist()
    main_backends()
    main_buffered()
