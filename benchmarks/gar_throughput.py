"""Proposition 1: aggregation cost.  Measures us/call for every GAR across
(n, d) and checks the two analytic claims:

  * Krum/GeoMed/Bulyan are O(n^2 d) — cost ~ linear in d at fixed n;
  * Bulyan(Krum) amortizes distance computation: its cost stays within a
    small factor of plain Krum (paper: same O(n^2 d) up to constants),
    NOT theta times Krum.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import get_gar


def _time(fn, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / reps


def main(ds=(10_000, 100_000, 1_000_000), ns=(15, 39)) -> None:
    key = jax.random.PRNGKey(0)
    results = {}
    for n in ns:
        f = (n - 3) // 4
        for d in ds:
            g = jax.random.normal(key, (n, d))
            for name in ("average", "cwmed", "trimmed_mean", "krum",
                         "geomed", "multikrum", "bulyan-krum",
                         "centered_clip"):
                gar = get_gar(name)
                jitted = jax.jit(lambda x, gar=gar: gar(x, f).gradient)
                us = _time(jitted, g)
                results[(name, n, d)] = us
                emit(f"gar_throughput/{name}_n{n}_d{d}", us,
                     f"bytes={4 * n * d}")
    # derived checks
    for n in ns:
        k = results[("krum", n, ds[-1])]
        b = results[("bulyan-krum", n, ds[-1])]
        emit(f"gar_throughput/bulyan_over_krum_n{n}", 0,
             f"ratio={b / k:.2f};amortized<<theta={n - 2 * ((n - 3) // 4)}")
        lin = results[("krum", n, ds[-1])] / results[("krum", n, ds[0])]
        emit(f"gar_throughput/krum_d_scaling_n{n}", 0,
             f"t(d*100)/t(d)={lin:.1f};expected~100(O(n^2 d))")


if __name__ == "__main__":
    main()
