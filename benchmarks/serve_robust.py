"""Robust ensemble decode throughput: tokens/sec vs replicas x rule x backend.

One jit'd ``make_robust_serve_step`` call decodes a token for every slot
on every replica and aggregates the ``(n, B, vocab)`` logits stack, so
the measured cost is ``n`` model forwards plus one registry-rule
application over ``B * vocab`` coordinates.  Rows compare ensemble sizes
``n`` across {average, krum, bulyan-krum} x {xla, pallas} — off-TPU the
pallas rows run the interpreter (a parity exercise, not a perf number,
exactly as in ``gar_throughput.main_backends``).

``derived`` reports ``tok_s`` (aggregate tokens/second across slots) and
``agg_overhead`` — the step-time ratio against the same ensemble under
plain ``average`` with the same backend column.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.agg import AggSpec
from repro.dist.serve_robust import (make_robust_serve_step, replicate_cache,
                                     replicate_params)
from repro.models import init_cache, init_model
from repro.models.config import ModelConfig

_SLOTS = 4
_CACHE = 64


def _bench_cfg() -> ModelConfig:
    return ModelConfig(
        name="serve-bench", arch_type="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )


def _time_step(step, stacked, cache, token, pos, state, reps: int = 10
               ) -> float:
    out = step(stacked, cache, token, pos, state)
    jax.block_until_ready(out[0])
    t0 = time.time()
    for _ in range(reps):
        out = step(stacked, cache, token, pos, state)
    jax.block_until_ready(out[0])
    return 1e6 * (time.time() - t0) / reps


def main(ns=(7, 11), gars=("average", "krum", "bulyan-krum"),
         backends=("xla", "pallas")) -> None:
    cfg = _bench_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    token = jnp.ones((_SLOTS, 1), jnp.int32)
    pos = jnp.full((_SLOTS,), 3, jnp.int32)
    for n in ns:
        f = (n - 3) // 4
        stacked = replicate_params(params, n, jitter=1e-3,
                                   key=jax.random.PRNGKey(1))
        cache = replicate_cache(init_cache(cfg, _SLOTS, _CACHE), n)
        for backend in backends:
            ref_us = None
            for gar in gars:
                spec = AggSpec(f=f, gar=gar, distance_backend=backend)
                step = jax.jit(make_robust_serve_step(cfg, spec))
                us = _time_step(step, stacked, cache, token, pos, None)
                if gar == "average":
                    ref_us = us
                tok_s = 1e6 * _SLOTS / us
                over = us / ref_us if ref_us else float("nan")
                emit(f"serve_robust/{gar}_n{n}", us,
                     f"tok_s={tok_s:.0f};agg_overhead={over:.2f}",
                     backend=backend)


if __name__ == "__main__":
    main()
