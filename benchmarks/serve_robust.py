"""Robust ensemble decode throughput: tokens/sec vs replicas x rule x backend.

One jit'd ``make_robust_serve_step`` call decodes a token for every slot
on every replica and aggregates the ``(n, B, vocab)`` logits stack, so
the measured cost is ``n`` model forwards plus one registry-rule
application over ``B * vocab`` coordinates.  Rows compare ensemble sizes
``n`` across {average, krum, bulyan-krum} x {xla, pallas} — off-TPU the
pallas rows run the interpreter (a parity exercise, not a perf number,
exactly as in ``gar_throughput.main_backends``).

``derived`` reports ``tok_s`` (aggregate tokens/second across slots) and
``agg_overhead`` — the step-time ratio against the same ensemble under
plain ``average`` with the same backend column.

The **speculative** rows (:func:`main_speculative`) benchmark the robust
speculative pipeline against the per-token path: one iteration is a
draft proposal (``k - 1`` single-replica decode steps in one jit'd
scan), one batched robust verify over the ``(B, k)`` block, and the
acceptance rule.  ``derived`` reports measured tokens/second, the mean
accepted tokens per iteration, ``p99_us`` per-iteration latency over the
sample loop, and ``speedup`` vs the same ensemble's per-token row — the
tiny byte-sized bench model keeps the rows meaningful off-TPU (dispatch
amortization and the batched verify dominate, exactly the effect the
speculative path targets).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.agg import AggSpec
from repro.dist.serve_robust import (make_robust_serve_step,
                                     make_robust_verify_step,
                                     replicate_cache, replicate_params)
from repro.models import init_cache, init_model
from repro.models.config import ModelConfig
from repro.serving.speculative import accept_block, make_draft_propose

_SLOTS = 4
_CACHE = 64


def _bench_cfg() -> ModelConfig:
    return ModelConfig(
        name="serve-bench", arch_type="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )


def _time_step(step, stacked, cache, token, pos, state, reps: int = 10
               ) -> float:
    out = step(stacked, cache, token, pos, state)
    jax.block_until_ready(out[0])
    t0 = time.time()
    for _ in range(reps):
        out = step(stacked, cache, token, pos, state)
    jax.block_until_ready(out[0])
    return 1e6 * (time.time() - t0) / reps


def _sample_iters(fn, reps: int) -> np.ndarray:
    """Per-iteration wall times (us) of ``fn`` after one warmup call."""
    jax.block_until_ready(fn())
    times = np.empty((reps,), np.float64)
    for i in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn())
        times[i] = 1e6 * (time.time() - t0)
    return times


def main(ns=(7, 11), gars=("average", "krum", "bulyan-krum"),
         backends=("xla", "pallas"), reps: int = 10) -> None:
    cfg = _bench_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    token = jnp.ones((_SLOTS, 1), jnp.int32)
    pos = jnp.full((_SLOTS,), 3, jnp.int32)
    for n in ns:
        f = (n - 3) // 4
        stacked = replicate_params(params, n, jitter=1e-3,
                                   key=jax.random.PRNGKey(1))
        cache = replicate_cache(init_cache(cfg, _SLOTS, _CACHE), n)
        for backend in backends:
            ref_us = None
            for gar in gars:
                spec = AggSpec(f=f, gar=gar, distance_backend=backend)
                step = jax.jit(make_robust_serve_step(cfg, spec))
                us = _time_step(step, stacked, cache, token, pos, None,
                                reps=reps)
                if gar == "average":
                    ref_us = us
                tok_s = 1e6 * _SLOTS / us
                over = us / ref_us if ref_us else float("nan")
                emit(f"serve_robust/{gar}_n{n}", us,
                     f"tok_s={tok_s:.0f};agg_overhead={over:.2f}",
                     backend=backend)


def main_speculative(ns=(7,), ks=(1, 2, 4), gars=("krum", "bulyan-krum"),
                     reps: int = 30) -> None:
    """Speculative-vs-per-token rows: tokens/sec, acceptance, p99.

    The per-token baseline row (``spec_pertoken_*``) times the PR-4
    robust serve step; each ``k`` row times a full speculative iteration
    (draft scan + batched robust verify + acceptance) and converts the
    *measured* accepted-token count into throughput, so a rejecting
    draft shows up as lost speedup, not a wrong number.  The draft is
    ensemble replica 0 of the jittered stack — the honest-draft regime.
    """
    cfg = _bench_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    token = jnp.ones((_SLOTS,), jnp.int32)
    pos = jnp.full((_SLOTS,), 3, jnp.int32)
    for n in ns:
        f = (n - 3) // 4
        stacked = replicate_params(params, n, jitter=1e-3,
                                   key=jax.random.PRNGKey(1))
        cache = replicate_cache(init_cache(cfg, _SLOTS, _CACHE), n)
        draft_cache = init_cache(cfg, _SLOTS, _CACHE)
        draft_params = jax.tree_util.tree_map(lambda x: x[0], stacked)
        for gar in gars:
            spec = AggSpec(f=f, gar=gar)
            serve = jax.jit(make_robust_serve_step(cfg, spec))
            times = _sample_iters(
                lambda: serve(stacked, cache, token[:, None], pos, None)[0],
                reps)
            us_tok = float(np.mean(times))
            base_tok_s = 1e6 * _SLOTS / us_tok
            emit(f"serve_robust/spec_pertoken_{gar}_n{n}", us_tok,
                 f"tok_s={base_tok_s:.0f};"
                 f"p99_us={float(np.percentile(times, 99)):.0f}")
            for k in ks:
                propose = jax.jit(make_draft_propose(cfg, k))
                verify = jax.jit(make_robust_verify_step(cfg, spec))
                accept = jax.jit(accept_block)

                def one_iter():
                    block, _dc = propose(draft_params, draft_cache,
                                         token, pos)
                    agg, _c, _diag, _st = verify(stacked, cache, block,
                                                 pos, None)
                    return accept(block, agg)

                times = _sample_iters(lambda: one_iter()[0], reps)
                us = float(np.mean(times))
                _, count, _ = one_iter()
                mean_acc = float(np.mean(np.asarray(count)))
                tok_s = 1e6 * _SLOTS * mean_acc / us
                emit(f"serve_robust/spec_{gar}_n{n}_k{k}", us,
                     f"tok_s={tok_s:.0f};accept={mean_acc:.2f};"
                     f"speedup={tok_s / base_tok_s:.2f};"
                     f"p99_us={float(np.percentile(times, 99)):.0f}")


def run(argv: Optional[Sequence[str]] = None) -> None:
    """CLI entry: per-token rows then speculative rows.

    ``--quick`` shrinks the grid to one ensemble size, the xla backend,
    ``k in (1, 4)`` and few reps — the CI smoke configuration.
    """
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: n=7, xla only, k in (1, 4), few reps")
    args = ap.parse_args(argv)
    if args.quick:
        main(ns=(7,), gars=("average", "krum"), backends=("xla",), reps=3)
        main_speculative(ns=(7,), ks=(1, 4), gars=("krum",), reps=5)
    else:
        main()
        main_speculative()


if __name__ == "__main__":
    run()
