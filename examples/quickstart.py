"""Quickstart: Byzantine-robust aggregation in 40 lines.

Runs one aggregation round on synthetic worker gradients, showing the
paper's headline result: the omniscient one-coordinate attack fully
poisons Krum, while Bulyan(Krum) stays at honest-noise level.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import get_attack, get_gar

n_honest, f, d = 12, 3, 10_000
key = jax.random.PRNGKey(0)

# honest workers: i.i.d. noisy estimates of the true gradient (= ones)
honest = jnp.ones((n_honest, d)) + 0.5 * jax.random.normal(key,
                                                           (n_honest, d))

# the omniscient adversary (§3.2): mean of honest + gamma on one
# coordinate, with gamma maximized subject to still being selected by Krum
byz = get_attack("omniscient_lp")(honest, f, None, gar_name="krum")
submissions = jnp.concatenate([honest, byz])

print(f"{'rule':<14} {'max |agg - honest_mean|':>24}   selected byz?")
mean = jnp.mean(honest, axis=0)
for rule in ("average", "krum", "geomed", "cwmed", "trimmed_mean",
             "bulyan-krum"):
    res = get_gar(rule)(submissions, f)
    dev = float(jnp.max(jnp.abs(res.gradient - mean)))
    picked = float(res.selected[-f:].sum()) > 0
    print(f"{rule:<14} {dev:>24.3f}   {picked}")

print("\nKrum is dragged by gamma_m = Theta(sqrt(d) * sigma) on the "
      "attacked coordinate;\nBulyan clamps the drag to O(sigma) "
      "(Proposition 2).")
