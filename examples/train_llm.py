"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the synthetic LM stream with Byzantine workers and
Bulyan(Krum) aggregation, with checkpointing.

    PYTHONPATH=src python examples/train_llm.py --steps 200

The model is a 12-layer, d=768 llama3-style decoder (~100M params).
NOTE on this 1-core container a 100M Byzantine step (7 worker grads +
in-graph attack + distributed Bulyan) takes ~60 s; pass --d-model 384
--steps 150 for a ~25M quick run with identical mechanics.  One training step is the full production path: per-worker
gradients -> in-graph omniscient attack -> distributed Bulyan -> AdamW.
On the 256-chip mesh this exact step function is what the dry-run lowers;
here it runs on CPU with n = 7 workers (f = 1).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.synthetic import lm_batches
from repro.dist.train import (DistByzantineSpec, init_agg_state,
                              make_train_step)
from repro.models import init_model
from repro.models.config import ModelConfig
from repro.optim import get_optimizer


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m", arch_type="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32768, head_dim=64,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--workers", type=int, default=7)
    ap.add_argument("--f", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--gar", default="bulyan-krum")
    ap.add_argument("--stream-vocab", type=int, default=2048,
                    help="vocab of the synthetic Markov stream (smaller "
                         "than the model's 32768 so a few hundred steps "
                         "visibly reduce loss)")
    ap.add_argument("--attack", default="omniscient_linf")
    ap.add_argument("--ckpt", default="artifacts/llm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    import dataclasses
    cfg = dataclasses.replace(model_100m(), d_model=args.d_model,
                              n_layers=args.layers,
                              n_heads=args.d_model // 64,
                              n_kv_heads=max(2, args.d_model // 192))
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params; "
          f"n={args.workers} workers (f={args.f}), gar={args.gar}, "
          f"attack={args.attack}")

    opt = get_optimizer("adamw", 3e-4, weight_decay=0.01)
    state = opt.init(params)
    start = 0
    if args.resume and os.path.exists(os.path.join(args.ckpt,
                                                   "manifest.json")):
        params, start = load_checkpoint(args.ckpt, params)
        print(f"resumed from step {start}")

    spec = DistByzantineSpec(f=args.f, gar=args.gar, attack=args.attack)
    step = jax.jit(make_train_step(cfg, spec, opt))
    # stateful GARs (buffered-*, centered_clip_momentum) carry an AggState
    agg_state = init_agg_state(spec, params, args.workers)

    n, b, s = args.workers, args.batch, args.seq
    t0 = time.time()
    for t in range(start, start + args.steps):
        toks, labs = [], []
        for w in range(n):
            x, y = lm_batches(args.stream_vocab, b, s, t * n + w, seed=7)
            toks.append(x)
            labs.append(y)
        batch = {"tokens": jnp.asarray(np.stack(toks)),
                 "labels": jnp.asarray(np.stack(labs))}
        if agg_state is not None:
            params, state, m, agg_state = step(params, state, batch,
                                               agg_state)
        else:
            params, state, m = step(params, state, batch)
        if t % 10 == 0 or t == start + args.steps - 1:
            dt = time.time() - t0
            tok_s = (t - start + 1) * n * b * s / max(dt, 1e-9)
            print(f"step {t:4d}  loss {float(m['loss']):.4f}  "
                  f"|g| {float(m['grad_norm']):.3f}  "
                  f"byz_w {float(m.get('byz_weight', 0)):.1f}  "
                  f"{tok_s:,.0f} tok/s", flush=True)
    save_checkpoint(args.ckpt, params, step=start + args.steps)
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
