"""Reproduce the paper's Fig. 2/4 dynamics in miniature: train the paper's
MNIST MLP with Byzantine workers under the §3.2 attack and watch accuracy
per aggregation rule.

    PYTHONPATH=src python examples/attack_demo.py [--steps 120] [--f 9]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data import ByzantineBatcher
from repro.data.synthetic import mnist_like
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import ByzantineSpec, ByzantineTrainer


def loss_fn(params, x, y):
    return simple.classification_loss(
        simple.mnist_mlp_forward(params, x), y, params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--n-honest", type=int, default=30)
    ap.add_argument("--f", type=int, default=9)
    ap.add_argument("--eta0", type=float, default=1.0)
    args = ap.parse_args()

    xe, ye = mnist_like(1500, 10 ** 6, seed=0)
    xe, ye = jnp.asarray(xe), jnp.asarray(ye)

    def eval_fn(params):
        return simple.accuracy(simple.mnist_mlp_forward(params, xe), ye)

    print(f"n = {args.n_honest}+{args.f}, eta0 = {args.eta0}, "
          f"attack = omniscient lp (closed-form gamma, 'top' coordinate)")
    for gar in ("average", "krum", "geomed", "bulyan-krum"):
        attack = "none" if gar == "average" else "omniscient_lp"
        f = 0 if gar == "average" else args.f
        base = gar.replace("bulyan-", "")
        spec = ByzantineSpec(n_workers=args.n_honest + f, f=f, gar=gar,
                             attack=attack,
                             attack_kwargs=(("gar_name", base),
                                            ("gamma", "closed"),
                                            ("coord", "top"),
                                            ("margin", 0.8)))
        tr = ByzantineTrainer(
            loss_fn, simple.init_mnist_mlp(jax.random.PRNGKey(1)),
            get_optimizer("sgd", fading_lr(args.eta0, 10000)), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 83, seed=1),
               args.steps, eval_fn=eval_fn, eval_every=args.steps // 6)
        curve = " ".join(f"{h['step']}:{h['eval_acc']:.2f}"
                         for h in tr.history if "eval_acc" in h)
        tag = f"{gar}{' (clean ref)' if gar == 'average' else ' (attacked)'}"
        print(f"{tag:<28} acc: {curve}  final={float(eval_fn(tr.params)):.3f}")


if __name__ == "__main__":
    main()
