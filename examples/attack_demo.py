"""Reproduce the paper's Fig. 2/4 dynamics in miniature: train the paper's
MNIST MLP with Byzantine workers under the §3.2 attack and watch accuracy
per aggregation rule.

    PYTHONPATH=src python examples/attack_demo.py [--steps 120] [--f 9]

``--async-tau N`` switches to the asynchronous bounded-staleness runtime
(mirroring serve_demo.py's poisoned-replica demo): honest workers
deliver through a GradientBus under staleness bound N and the Byzantine
workers run the stale-replay attack — replaying a once-credible stale
gradient forever while stamping fresh arrivals.  Plain ``average`` is
flipped away from the converged clean run; the staleness-aware
``stale-krum`` / ``stale-bulyan-krum`` rules hold (see
docs/async-runtime.md):

    PYTHONPATH=src python examples/attack_demo.py --async-tau 3
"""
import argparse

import jax
import jax.numpy as jnp

from repro.data import ByzantineBatcher
from repro.data.synthetic import mnist_like
from repro.models import simple
from repro.optim import fading_lr, get_optimizer
from repro.training import (AsyncByzantineTrainer, ByzantineSpec,
                            ByzantineTrainer)


def loss_fn(params, x, y):
    return simple.classification_loss(
        simple.mnist_mlp_forward(params, x), y, params)


def main_async(args):
    """Stale-replay vs the staleness-aware rules under bounded staleness."""
    xe, ye = mnist_like(1500, 10 ** 6, seed=0, noise=0.5)
    xe, ye = jnp.asarray(xe), jnp.asarray(ye)

    def eval_fn(params):
        return simple.accuracy(simple.mnist_mlp_forward(params, xe), ye)

    tau = args.async_tau
    print(f"async runtime: tau = {tau} (staggered fixed schedule), "
          f"n = {args.n_honest}+{args.f}, attack = stale-replay "
          f"(amplified stale content re-recorded every tau+1 steps)")
    accs = {}
    for gar, attack, f in (("average", "none", 0),
                           ("average", "stale_replay", args.f),
                           ("stale-krum", "stale_replay", args.f),
                           ("stale-bulyan-krum", "stale_replay", args.f)):
        spec = ByzantineSpec(
            n_workers=args.n_honest + f, f=f, gar=gar, attack=attack,
            async_tau=tau,
            attack_kwargs=(("scale", -4.0), ("hold", tau + 1))
            if f else ())
        tr = AsyncByzantineTrainer(
            loss_fn, simple.init_mnist_mlp(jax.random.PRNGKey(1)),
            get_optimizer("sgd", fading_lr(args.eta0, 10000)), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 83, seed=1,
                                noise=0.5),
               args.steps, eval_fn=eval_fn, eval_every=args.steps // 6)
        curve = " ".join(f"{h['step']}:{h['eval_acc']:.2f}"
                         for h in tr.history if "eval_acc" in h)
        final = float(eval_fn(tr.params))
        accs[(gar, attack)] = final
        tag = f"{gar}{' (clean ref)' if attack == 'none' else ' (attacked)'}"
        print(f"{tag:<32} acc: {curve}  final={final:.3f}  "
              f"stal_mean={tr.history[-1]['staleness_mean']:.2f}")
    clean = accs[("average", "none")]
    flipped = accs[("average", "stale_replay")] < clean - 0.15
    held = all(accs[(g, "stale_replay")] > clean - 0.05
               for g in ("stale-krum", "stale-bulyan-krum"))
    print(f"stale-replay flips the converged average run: "
          f"{'YES' if flipped else 'NO'}")
    print(f"stale-krum / stale-bulyan-krum hold: "
          f"{'YES' if held else 'NO'}")
    if not (flipped and held):
        raise SystemExit("demo expectation failed")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--n-honest", type=int, default=30)
    ap.add_argument("--f", type=int, default=9)
    ap.add_argument("--eta0", type=float, default=1.0)
    ap.add_argument("--async-tau", type=int, default=None,
                    help="run the asynchronous bounded-staleness demo "
                         "with this staleness bound (stale-replay vs "
                         "stale-krum/stale-bulyan)")
    args = ap.parse_args()

    if args.async_tau is not None:
        main_async(args)
        return

    xe, ye = mnist_like(1500, 10 ** 6, seed=0)
    xe, ye = jnp.asarray(xe), jnp.asarray(ye)

    def eval_fn(params):
        return simple.accuracy(simple.mnist_mlp_forward(params, xe), ye)

    print(f"n = {args.n_honest}+{args.f}, eta0 = {args.eta0}, "
          f"attack = omniscient lp (closed-form gamma, 'top' coordinate)")
    for gar in ("average", "krum", "geomed", "bulyan-krum"):
        attack = "none" if gar == "average" else "omniscient_lp"
        f = 0 if gar == "average" else args.f
        base = gar.replace("bulyan-", "")
        spec = ByzantineSpec(n_workers=args.n_honest + f, f=f, gar=gar,
                             attack=attack,
                             attack_kwargs=(("gar_name", base),
                                            ("gamma", "closed"),
                                            ("coord", "top"),
                                            ("margin", 0.8)))
        tr = ByzantineTrainer(
            loss_fn, simple.init_mnist_mlp(jax.random.PRNGKey(1)),
            get_optimizer("sgd", fading_lr(args.eta0, 10000)), spec)
        tr.run(ByzantineBatcher("mnist", spec.n_honest, 83, seed=1),
               args.steps, eval_fn=eval_fn, eval_every=args.steps // 6)
        curve = " ".join(f"{h['step']}:{h['eval_acc']:.2f}"
                         for h in tr.history if "eval_acc" in h)
        tag = f"{gar}{' (clean ref)' if gar == 'average' else ' (attacked)'}"
        print(f"{tag:<28} acc: {curve}  final={float(eval_fn(tr.params)):.3f}")


if __name__ == "__main__":
    main()
