"""Batched serving demo: run the continuous-batching engine over a small
llama-family model with staggered requests.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.models import init_model
from repro.models.config import ModelConfig
from repro.serving import Request, ServingEngine


def small_model() -> ModelConfig:
    return ModelConfig(
        name="llama-serve-demo", arch_type="dense",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=4096, head_dim=32,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )


def main():
    cfg = small_model()
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, n_slots=4, cache_len=128)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=5 + 3 * i)
                .astype(np.int32), max_new_tokens=8 + 2 * i)
        for i in range(7)
    ]
    t0 = time.time()
    results = engine.run(requests, max_steps=200)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests / {total_tokens} tokens in "
          f"{dt:.1f}s with 4 slots")
    for rid in sorted(results):
        print(f"  req {rid}: {len(results[rid])} tokens -> "
              f"{results[rid][:8]}{'...' if len(results[rid]) > 8 else ''}")


if __name__ == "__main__":
    main()
