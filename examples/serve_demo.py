"""Batched serving demo: continuous batching, optionally Byzantine-robust.

Default mode runs the continuous-batching engine over a small
llama-family model with staggered requests:

    PYTHONPATH=src python examples/serve_demo.py

Ensemble mode serves an ensemble of replicas of which the last
``--serve-f`` are *poisoned* (their parameters rewritten by the
training-side Byzantine attack machinery), and compares greedy decode
under plain averaging vs the requested robust rule:

    PYTHONPATH=src python examples/serve_demo.py \\
        --ensemble 8 --serve-f 2 --serve-gar bulyan

The poisoned replica flips the argmax stream under ``average``; under
Krum/Bulyan the ensemble's output matches the attack-free run token for
token.  If the requested ensemble is below the rule's quorum
(Bulyan needs n >= 4f + 3), it is raised to the minimum and a note is
printed.  See docs/serving.md.
"""
import argparse
import time

import jax
import numpy as np

from repro.agg import AggSpec, quorum
from repro.dist.serve_robust import poison_replicas, replicate_params
from repro.models import init_model
from repro.models.config import ModelConfig
from repro.serving import Request, ServingEngine


def small_model() -> ModelConfig:
    return ModelConfig(
        name="llama-serve-demo", arch_type="dense",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=4096, head_dim=32,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )


def make_requests(cfg, n=7):
    rng = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=5 + 3 * i)
                .astype(np.int32), max_new_tokens=8 + 2 * i)
        for i in range(n)
    ]


def main_plain():
    cfg = small_model()
    params = init_model(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, n_slots=4, cache_len=128)
    requests = make_requests(cfg)
    t0 = time.time()
    results = engine.run(requests, max_steps=200)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests / {total_tokens} tokens in "
          f"{dt:.1f}s with 4 slots")
    for rid in sorted(results):
        print(f"  req {rid}: {len(results[rid])} tokens -> "
              f"{results[rid][:8]}{'...' if len(results[rid]) > 8 else ''}")


def main_ensemble(args):
    cfg = small_model()
    params = init_model(jax.random.PRNGKey(0), cfg)
    n, f = args.ensemble, args.serve_f
    need = quorum(args.serve_gar, f)
    if n < need:
        print(f"note: {args.serve_gar} needs n >= {need} for f={f}; "
              f"raising ensemble from {n} to {need}")
        n = need

    honest = replicate_params(params, n, jitter=args.jitter,
                              key=jax.random.PRNGKey(1))
    poisoned = poison_replicas(honest, f, args.poison,
                               scale=args.poison_scale)
    requests = make_requests(cfg, n=4)

    def serve(stacked, gar):
        spec = AggSpec(f=f, gar=gar)
        eng = ServingEngine(stacked, cfg, n_slots=4, cache_len=128,
                            ensemble=spec)
        reqs = [Request(rid=r.rid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens) for r in requests]
        t0 = time.time()
        out = eng.run(reqs, max_steps=200)
        return out, time.time() - t0

    print(f"ensemble of {n} replicas, last {f} poisoned "
          f"({args.poison}, scale={args.poison_scale}), "
          f"gar={args.serve_gar}")
    clean, dt_c = serve(honest, args.serve_gar)
    att_gar, dt_g = serve(poisoned, args.serve_gar)
    att_avg, dt_a = serve(poisoned, "average")
    toks = sum(len(v) for v in clean.values())
    print(f"  {toks} tokens/run in {dt_c:.1f}s (clean) / {dt_g:.1f}s "
          f"({args.serve_gar} under attack) / {dt_a:.1f}s (average)")
    robust_ok = all(att_gar[r] == clean[r] for r in clean)
    avg_flipped = any(att_avg[r] != clean[r] for r in clean)
    for rid in sorted(clean):
        mark_g = "==" if att_gar[rid] == clean[rid] else "!="
        mark_a = "==" if att_avg[rid] == clean[rid] else "!="
        print(f"  req {rid}: no-attack {clean[rid][:6]}... | "
              f"{args.serve_gar} {mark_g} no-attack | average {mark_a} "
              f"no-attack")
    print(f"{args.serve_gar} rejects the poisoned replica: "
          f"{'YES' if robust_ok else 'NO'}")
    print(f"average is steered by the poisoned replica: "
          f"{'YES' if avg_flipped else 'NO'}")
    if not (robust_ok and avg_flipped):
        raise SystemExit("demo expectation failed")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ensemble", type=int, default=0,
                    help="ensemble size (0 = plain single-model demo)")
    ap.add_argument("--serve-f", type=int, default=2,
                    help="number of poisoned replicas / declared bound")
    ap.add_argument("--serve-gar", default="bulyan",
                    help="robust aggregation rule (any repro.agg name)")
    ap.add_argument("--poison", default="signflip",
                    help="parameter attack on the last f replicas")
    ap.add_argument("--poison-scale", type=float, default=10.0)
    ap.add_argument("--jitter", type=float, default=1e-3,
                    help="honest replica jitter (independent fine-tunes)")
    args = ap.parse_args()
    if args.ensemble > 0:
        main_ensemble(args)
    else:
        main_plain()


if __name__ == "__main__":
    main()
