"""Byzantine attacks.

The paper's attack (§3.2/§3.3): the omniscient adversary waits for the
n - f honest gradients, submits ``B(gamma) = mean(honest) + gamma * E`` with
``E`` a one-hot coordinate (finite p) or the all-ones vector (l-inf), and
chooses the largest ``gamma`` still *selected* by the aggregation rule.  The
paper estimates gamma_m "by a simple linear regression"; we instead run an
in-graph geometric-growth + bisection search against the actual rule, which
is exact up to tolerance and jit-compatible.

Beyond-paper attacks used as additional benchmark adversaries: ALIE
("A Little Is Enough", Baruch et al. 2019), IPM (inner-product manipulation,
Xie et al. 2019), sign-flip, mimic, random, zero.  The asynchronous
runtime adds two delay-exploiting adversaries — ``stale_replay`` and
``slow_drift`` — which additionally read ``prev`` (their own previous
bus submissions, threaded by the async step builders; see
``repro.dist.async_train`` and docs/async-runtime.md).  The reputation
runtime (``repro.agg.reputation``, docs/reputation.md) adds
``reputation_burn`` (build trust honestly, then spend it on sign-flipped
ascent — step-threaded like the delay attacks) and ``colluding_majority``
(f identical submissions a bounded distance off the honest mean — the
arbitrary-f adversary that defeats every quorum rule at f >= n/2).

All attacks have the signature::

    attack(honest: (n_h, d), f: int, key, **kw) -> (f, d)

and are registered in ``ATTACKS``.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import gars
from repro.core.types import AttackResult


# ---------------------------------------------------------------------------
# selection checkers
# ---------------------------------------------------------------------------

def make_selection_checker(gar_name: str, f: int) -> Callable:
    """Return ``check(full_grads) -> bool`` — True when at least one of the
    *last f rows* (the Byzantine submissions) carries weight in the rule's
    output.  Used by the gamma_m search."""
    gar = gars.get_gar(gar_name)

    def check(full_grads: jnp.ndarray) -> jnp.ndarray:
        res = gar(full_grads, f)
        return jnp.sum(res.selected[-f:]) > 0

    return check


# ---------------------------------------------------------------------------
# gamma_m search (the "linear regression" of §3.2, done properly)
# ---------------------------------------------------------------------------

def find_gamma_max(honest: jnp.ndarray, f: int, direction: jnp.ndarray,
                   check: Callable, gamma0: float = 1e-3,
                   n_grow: int = 26, n_bisect: int = 30) -> jnp.ndarray:
    """Largest gamma such that ``mean(honest) + gamma * direction`` is still
    selected by the rule (per ``check``).  Geometric growth to bracket, then
    bisection.  Fully in-graph (static iteration counts)."""
    mean = jnp.mean(honest, axis=0)

    def selected(gamma):
        byz = mean[None, :] + gamma * direction[None, :]
        full = jnp.concatenate([honest, jnp.repeat(byz, f, axis=0)], axis=0)
        return check(full)

    # growth phase: lo = largest gamma seen selected, hi = smallest gamma
    # seen rejected
    def grow_body(_, carry):
        lo, hi, g = carry
        sel = selected(g)
        lo = jnp.where(sel & (g > lo), g, lo)
        hi = jnp.where((~sel) & (g < hi), g, hi)
        return lo, hi, g * 2.0

    lo, hi, _ = jax.lax.fori_loop(
        0, n_grow, grow_body,
        (jnp.asarray(0.0, honest.dtype), jnp.asarray(jnp.inf, honest.dtype),
         jnp.asarray(gamma0, honest.dtype)))
    # if never rejected, the attack is unbounded within the probed range
    hi = jnp.where(jnp.isfinite(hi), hi, lo * 2.0 + gamma0)

    def bisect_body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        sel = selected(mid)
        return jnp.where(sel, mid, lo), jnp.where(sel, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_bisect, bisect_body, (lo, hi))
    return lo


def gamma_closed_form(rule: str, d: int, f: int, delta_bar: float,
                      p: int = 2) -> float:
    """The paper's §B approximations of gamma_m (order-of-magnitude only).

    Brute:        gamma_m ~ ((1 - 2^{-p/2}) d)^{1/p} * delta_bar
    Krum/GeoMed:  gamma_m ~ ((f+1)^{p/q} - 2^{-p/2})^{1/p} d^{1/p} * delta_bar
                  with q=2 for Krum, q=1 for GeoMed and b=0.
    """
    if rule == "brute":
        return float(((1.0 - 2.0 ** (-p / 2.0)) * d) ** (1.0 / p) * delta_bar)
    q = 2.0 if rule == "krum" else 1.0
    b = 0.0
    inner = ((f + 1.0 - b) / (2.0 - b)) ** (p / q) - 2.0 ** (-p / 2.0)
    return float(max(inner, 1e-9) ** (1.0 / p) * d ** (1.0 / p) * delta_bar)


# ---------------------------------------------------------------------------
# the paper's attacks
# ---------------------------------------------------------------------------

def _delta_bar(honest: jnp.ndarray) -> jnp.ndarray:
    """Paper §B.1: average folded std per coordinate, E|v_i - v_j| =
    2 sigma / sqrt(pi) for gaussian coordinates."""
    return 2.0 / jnp.sqrt(jnp.pi) * jnp.mean(jnp.std(honest, axis=0))


def _closed_rule(gar_name: str) -> str:
    """Normalize a GAR name to the rule family §B's closed forms cover:
    ``bulyan-<base>`` collapses to its base, anything without its own
    estimate falls back to krum's."""
    base = (gar_name.split("-", 1)[1] if gar_name.startswith("bulyan-")
            else gar_name)
    return base if base in ("krum", "geomed", "brute") else "krum"


def _closed_gamma(rule: str, d: int, f: int, db: jnp.ndarray, p: int = 2
                  ) -> jnp.ndarray:
    """Traced-friendly version of ``gamma_closed_form`` (§B.2/§B.3)."""
    rule = _closed_rule(rule)
    if rule == "brute":
        return ((1.0 - 2.0 ** (-p / 2.0)) * d) ** (1.0 / p) * db
    q = 2.0 if rule == "krum" else 1.0
    inner = jnp.maximum(((f + 1.0) / 2.0) ** (p / q) - 2.0 ** (-p / 2.0),
                        1e-9)
    return inner ** (1.0 / p) * d ** (1.0 / p) * db


def omniscient_lp(honest: jnp.ndarray, f: int, key=None, *,
                  coord=0, gamma=None,
                  gar_name: str = "krum", margin: float = 1.0,
                  step=None) -> jnp.ndarray:
    """§3.2: one poisoned coordinate, magnitude just inside the selection
    margin (gamma_m * margin).

    gamma: None -> exact in-graph growth+bisection search against the rule;
           "closed" -> the paper's §B closed-form estimate (cheap, 1 pass);
           float -> fixed.
    coord: int | "rotate" (coordinate step mod d — the adversary may pick a
           new coordinate each round) | "top" (the coordinate the honest
           mean considers most important, attacked *against* its sign).
    """
    d = honest.shape[1]
    mean = jnp.mean(honest, axis=0)
    sign = 1.0
    if coord == "rotate":
        c = (jnp.asarray(step, jnp.int32) if step is not None
             else jnp.zeros((), jnp.int32)) % d
    elif coord == "top":
        c = jnp.argmax(jnp.abs(mean))
        sign = -jnp.sign(mean[c])
    else:
        c = jnp.asarray(coord, jnp.int32)
    e = (jnp.zeros((d,), honest.dtype).at[c].set(1.0)) * sign
    if gamma is None:
        check = make_selection_checker(gar_name, f)
        g = find_gamma_max(honest, f, e, check) * margin
    elif gamma == "closed":
        g = _closed_gamma(gar_name, d, f, _delta_bar(honest)) * margin
    else:
        g = jnp.asarray(gamma, honest.dtype)
    byz = mean[None, :] + g * e[None, :]
    return jnp.repeat(byz, f, axis=0)


def omniscient_linf(honest: jnp.ndarray, f: int, key=None, *,
                    gamma=None, gar_name: str = "krum",
                    margin: float = 1.0, step=None,
                    direction: str = "ones") -> jnp.ndarray:
    """§3.3: poison *every* coordinate by gamma.  E = all-ones, or
    ``direction="anti"``: against the sign of the honest mean (the
    omniscient adversary's worst-case choice of the +-1 vector)."""
    d = honest.shape[1]
    mean = jnp.mean(honest, axis=0)
    if direction == "anti":
        e = -jnp.sign(mean)
        e = jnp.where(e == 0, 1.0, e).astype(honest.dtype)
    else:
        e = jnp.ones((d,), honest.dtype)
    if gamma is None:
        check = make_selection_checker(gar_name, f)
        g = find_gamma_max(honest, f, e, check) * margin
    elif gamma == "closed":
        # per-coordinate leeway ~ delta_bar (no sqrt(d) amplification: the
        # lp distance grows with every poisoned coordinate)
        g = _delta_bar(honest) * margin
    else:
        g = jnp.asarray(gamma, honest.dtype)
    byz = mean[None, :] + g * e[None, :]
    return jnp.repeat(byz, f, axis=0)


# ---------------------------------------------------------------------------
# beyond-paper attacks
# ---------------------------------------------------------------------------

def alie(honest: jnp.ndarray, f: int, key=None, *, z: Optional[float] = None
         ) -> jnp.ndarray:
    """"A Little Is Enough": shift every coordinate by z_max standard
    deviations — small enough to evade distance tests, coordinated enough to
    bias the aggregate."""
    n_h = honest.shape[0]
    n = n_h + f
    if z is None:
        # supporters needed for a (corrupted) majority
        s = (n // 2) + 1 - f
        phi = max(min((n - f - s) / float(n - f), 1.0 - 1e-6), 1e-6)
        z = float(jax.scipy.special.ndtri(phi))
    mu = jnp.mean(honest, axis=0)
    sd = jnp.std(honest, axis=0)
    byz = mu - z * sd
    return jnp.repeat(byz[None, :], f, axis=0)


def ipm(honest: jnp.ndarray, f: int, key=None, *, eps: float = 0.5
        ) -> jnp.ndarray:
    """Inner-product manipulation: submit -eps * mean(honest); flips the
    aggregate's inner product with the true gradient when selected."""
    byz = -eps * jnp.mean(honest, axis=0)
    return jnp.repeat(byz[None, :], f, axis=0)


def signflip(honest: jnp.ndarray, f: int, key=None, *, scale: float = 1.0
             ) -> jnp.ndarray:
    byz = -scale * jnp.mean(honest, axis=0)
    return jnp.repeat(byz[None, :], f, axis=0)


def random_noise(honest: jnp.ndarray, f: int, key, *, scale: float = 10.0
                 ) -> jnp.ndarray:
    d = honest.shape[1]
    return scale * jax.random.normal(key, (f, d), honest.dtype)


def zero(honest: jnp.ndarray, f: int, key=None) -> jnp.ndarray:
    return jnp.zeros((f, honest.shape[1]), honest.dtype)


def mimic(honest: jnp.ndarray, f: int, key=None, *, target: int = 0
          ) -> jnp.ndarray:
    """Copy one honest worker — starves diversity-dependent rules."""
    return jnp.repeat(honest[target][None, :], f, axis=0)


# ---------------------------------------------------------------------------
# delay-exploiting attacks (the asynchronous runtime's adversaries)
# ---------------------------------------------------------------------------
#
# Both read ``prev`` — the Byzantine rows of the previous GradientBus —
# which only the async step builders thread through (repro.dist.async_train
# / repro.training.trainer).  Called without ``prev`` (the synchronous
# runtime) they degenerate to a mimic-the-mean submission each step.

def stale_replay(honest: jnp.ndarray, f: int, key=None, *,
                 prev: Optional[jnp.ndarray] = None, step=None,
                 hold: int = 0, scale: float = 1.0) -> jnp.ndarray:
    """Replay a once-credible gradient forever (async ε-analogue, part 1).

    At step 0 the omniscient adversary records the honest mean — a
    perfectly legitimate submission — scaled by ``scale``, then resubmits
    it *unchanged* every step while stamping a fresh arrival on the bus.
    Under bounded staleness an old honest gradient is expected, so the
    replay hides in the leeway asynchrony opens; as honest training
    moves on, the frozen early-training direction keeps over-applying
    itself through the average (``scale`` amplifies the replayed
    magnitude, ``scale < 0`` replays the *ascent* direction — the
    classic poisoned-replay variants).  ``hold > 0`` re-records every
    ``hold`` steps (a replay window instead of a full freeze)."""
    mean = jnp.mean(honest, axis=0)
    rec = jnp.repeat(scale * mean[None, :], f, axis=0)
    if prev is None:
        return rec
    t = jnp.asarray(step if step is not None else 0, jnp.int32)
    refresh = t == 0
    if hold > 0:
        refresh = refresh | (t % hold == 0)
    return jnp.where(refresh, rec, prev.astype(rec.dtype)
                     ).astype(honest.dtype)


def slow_drift(honest: jnp.ndarray, f: int, key=None, *,
               prev: Optional[jnp.ndarray] = None, step=None,
               eps: float = 0.5, direction: str = "anti") -> jnp.ndarray:
    """Drift from the honest mean by eps * delta_bar per step (part 2).

    The async analogue of the paper's ε-perturbation: each submission
    differs from the adversary's *previous* one by less than the honest
    workers' own per-step spread (delta_bar, §B.1), so no single step is
    distinguishable from an honest straggler — but the drift integrates
    into an O(steps) displacement along ``direction`` ("anti": against
    the sign of the current honest mean; "ones": the all-ones vector)."""
    mean = jnp.mean(honest, axis=0)
    rec = jnp.repeat(mean[None, :], f, axis=0)
    if direction == "anti":
        e = -jnp.sign(mean)
        e = jnp.where(e == 0, 1.0, e).astype(honest.dtype)
    else:
        e = jnp.ones_like(mean)
    db = _delta_bar(honest)
    if prev is None:
        return rec + eps * db * e[None, :]
    t = jnp.asarray(step if step is not None else 0, jnp.int32)
    drifted = prev.astype(jnp.float32) + eps * db * e[None, :]
    return jnp.where(t == 0, rec, drifted).astype(honest.dtype)


# ---------------------------------------------------------------------------
# reputation attacks (the arbitrary-f runtime's adversaries)
# ---------------------------------------------------------------------------
#
# Adversaries of the ``reputation-*`` rules (repro.agg.reputation).  Both
# thread ``step`` like the delay attacks thread ``prev``; called without
# it they behave as their step-0 form.

def reputation_burn(honest: jnp.ndarray, f: int, key=None, *,
                    prev: Optional[jnp.ndarray] = None, step=None,
                    build: int = 5, scale: float = 3.0) -> jnp.ndarray:
    """Build trust honestly, then burn it (the reputation analogue of
    ``stale_replay``).

    For the first ``build`` steps the adversary submits the honest mean —
    a perfect-agreement submission that drives its reputation score to
    the maximum — then switches to ``-scale * mean``, spending the
    accumulated trust on sign-flipped ascent.  Against a reputation rule
    the EMA must *monotonically* burn the attacker's score back down
    after the flip (pinned by ``tests/test_reputation.py``); against a
    stateless rule the attack degenerates to delayed ``signflip``.
    ``prev`` is accepted for signature parity with the delay attacks but
    unused — the burn schedule is a pure function of ``step``."""
    del prev  # signature parity with the delay-exploiting attacks
    mean = jnp.mean(honest, axis=0)
    t = jnp.asarray(step if step is not None else 0, jnp.int32)
    byz = jnp.where(t < build, mean, -scale * mean)
    return jnp.repeat(byz[None, :], f, axis=0)


def colluding_majority(honest: jnp.ndarray, f: int, key=None, *,
                       eps: float = 4.0,
                       direction: str = "random") -> jnp.ndarray:
    """f identical colluders a bounded distance off the honest mean.

    The arbitrary-f adversary: all ``f`` Byzantine workers submit the
    *same* point ``mean + eps * delta_bar * u`` (``u`` a unit
    direction).  At ``f >= n/2`` the colluding cluster is the tightest
    neighborhood in the stack, so every distance-based selection rule
    whose quorum was (wrongly) declared satisfied picks a colluder, and
    coordinate-wise rules place the median inside the cluster — only
    auxiliary-batch reputation scoring (``AggSpec(aux_batch=...)``)
    recovers, since agreement with the clean gradient is the one signal
    the colluders cannot vote on.  ``eps`` scales the offset in units
    of the honest spread (§B.1 delta_bar), keeping each colluder
    individually plausible.

    ``direction`` picks ``u`` (mirroring ``omniscient_linf``):
    ``"random"`` draws a fresh unit vector from ``key`` — in high
    dimension nearly orthogonal to the honest mean, so the cluster
    drags the aggregate sideways; ``"anti"`` sets ``u = -mean/|mean|``,
    the descent-reversing worst case that cosine-based reputation
    scoring punishes hardest."""
    d = honest.shape[1]
    mean = jnp.mean(honest, axis=0)
    if direction == "anti":
        u = -(mean / (jnp.linalg.norm(mean) + 1e-12))
    elif direction == "random":
        if key is None:
            key = jax.random.PRNGKey(0)
        u = jax.random.normal(key, (d,), jnp.float32)
        u = (u / (jnp.linalg.norm(u) + 1e-12)).astype(honest.dtype)
    else:
        raise ValueError(
            f"colluding_majority direction must be 'random' or 'anti', "
            f"got {direction!r}")
    byz = mean + eps * _delta_bar(honest) * u
    return jnp.repeat(byz[None, :], f, axis=0)


ATTACKS = {
    "none": None,
    "omniscient_lp": omniscient_lp,
    "omniscient_linf": omniscient_linf,
    "alie": alie,
    "ipm": ipm,
    "signflip": signflip,
    "random": random_noise,
    "zero": zero,
    "mimic": mimic,
    "stale_replay": stale_replay,
    "slow_drift": slow_drift,
    "reputation_burn": reputation_burn,
    "colluding_majority": colluding_majority,
}


def get_attack(name: str):
    if name not in ATTACKS:
        raise KeyError(f"unknown attack {name!r}; have {sorted(ATTACKS)}")
    return ATTACKS[name]
