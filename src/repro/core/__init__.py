"""Byzantine-robust aggregation core (the paper's contribution).

Public API::

    from repro.core import get_gar, get_attack, aggregate_pytree
    agg = get_gar("bulyan-krum")(grads, f)        # grads: (n, d)
    byz = get_attack("omniscient_lp")(honest, f, key, gar_name="krum")
"""
from repro.core.gars import (REGISTRY, average, brute, centered_clip, cwmed,
                             geomed, get_gar, krum, multikrum,
                             pairwise_sq_dists, quorum, trimmed_mean)
from repro.core.bulyan import (coordinate_phase, coordinate_phase_ref,
                               make_bulyan, select_indices)
from repro.core.attacks import (ATTACKS, find_gamma_max, gamma_closed_form,
                                get_attack, make_selection_checker)
from repro.core.pytree import aggregate_pytree, stack_flatten, unflatten
from repro.core.types import AggResult, AttackResult, GarSpec

__all__ = [
    "REGISTRY", "ATTACKS", "AggResult", "AttackResult", "GarSpec",
    "aggregate_pytree", "average", "brute", "centered_clip",
    "coordinate_phase", "coordinate_phase_ref", "cwmed", "find_gamma_max",
    "gamma_closed_form", "geomed", "get_attack", "get_gar", "krum",
    "make_bulyan", "make_selection_checker", "multikrum",
    "pairwise_sq_dists", "quorum", "select_indices", "stack_flatten",
    "trimmed_mean", "unflatten",
]
