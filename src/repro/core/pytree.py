"""Pytree <-> flat-matrix adapters for the GAR core.

The core GARs operate on ``(n, d)`` matrices.  Training code holds per-worker
gradients as a pytree whose leaves carry a leading worker axis
``(n, *param_shape)``.  These helpers flatten/unflatten without copying more
than once, and ``aggregate_pytree`` applies any registered GAR to such a
stacked-gradient pytree.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import gars
from repro.core.types import AggResult


def stack_flatten(stacked_tree: Any) -> Tuple[jnp.ndarray, Any]:
    """Pytree of (n, *shape) leaves -> ((n, d) matrix, unravel context)."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked_tree)
    n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [leaf.reshape(n, -1).astype(jnp.float32) for leaf in leaves], axis=1)
    shapes = [(leaf.shape[1:], leaf.dtype) for leaf in leaves]
    return flat, (treedef, shapes)


def unflatten(vec: jnp.ndarray, ctx: Any) -> Any:
    """(d,) vector -> pytree of per-parameter leaves."""
    treedef, shapes = ctx
    leaves, off = [], 0
    for shape, dtype in shapes:
        size = 1
        for s in shape:
            size *= s
        leaves.append(vec[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, leaves)


def aggregate_pytree(stacked_tree: Any, gar_name: str, f: int) -> Tuple[Any, AggResult]:
    """Apply GAR ``gar_name`` across the leading worker axis of a stacked
    gradient pytree.  Returns (aggregated pytree, AggResult diagnostics)."""
    gar = gars.get_gar(gar_name)
    flat, ctx = stack_flatten(stacked_tree)
    res = gar(flat, f)
    return unflatten(res.gradient, ctx), res
