"""Common types for the Byzantine-robust aggregation core.

Every gradient aggregation rule (GAR) in this package operates on a stacked
gradient matrix ``grads`` of shape ``(n, d)`` — one row per worker — plus a
*static* Byzantine bound ``f``.  The pytree-aware wrappers live in
``repro.core.pytree`` and the mesh-sharded implementations in ``repro.dist``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple

import jax.numpy as jnp


class AggResult(NamedTuple):
    """Result of one aggregation.

    gradient:  (d,) the aggregated gradient.
    selected:  (n,) float mask — 1.0 where the worker's submission took part
               in the final linear combination (selection-based rules), or
               fractional weights (e.g. averaging).  Purely diagnostic.
    scores:    (n,) per-worker score used by the rule (lower = better), or
               zeros when the rule is score-free.
    """

    gradient: jnp.ndarray
    selected: jnp.ndarray
    scores: jnp.ndarray


# A GAR is a callable (grads: (n, d), f: int) -> AggResult.  ``f`` must be a
# static Python int (it controls top-k sizes and unrolled loops).
GarFn = Callable[..., AggResult]


@dataclasses.dataclass(frozen=True)
class GarSpec:
    """Registry entry for a gradient aggregation rule."""

    name: str
    fn: GarFn
    #: minimal worker count as a function of f (paper §2.3 / §4)
    min_n: Callable[[int], int]
    #: True when the rule is proven (alpha, f)-Byzantine-resilient
    byzantine_resilient: bool
    doc: str = ""

    def check_quorum(self, n: int, f: int) -> None:
        need = self.min_n(f)
        if n < need:
            raise ValueError(
                f"GAR {self.name!r} requires n >= {need} for f={f}, got n={n}"
            )


class AttackResult(NamedTuple):
    """Byzantine submissions plus diagnostics."""

    byzantine: jnp.ndarray  # (f, d)
    info: Dict[str, Any]
