"""Bulyan(A) — the paper's contribution (§4).

Two phases:

1. *Recursive selection*: repeatedly run the base (alpha, f)-Byzantine-
   resilient rule ``A`` on the remaining received set, each time moving the
   proposed vector closest to A's output into the selection set, until
   theta = n - 2f vectors are selected.  For Krum / the Medoid, "closest to
   A's output" is exactly A's output index.  Pairwise distances are computed
   once and sub-indexed across iterations (Proposition 1's amortization).

2. *Coordinate-wise aggregation*: for each coordinate i, output the average
   of the beta = theta - 2f values closest to the coordinate-wise median
   (the median being the minimizer, among proposed values, of the sum of
   absolute deviations — a 1-D medoid).

The coordinate phase is exposed standalone (``coordinate_phase``) because it
is what the Pallas kernel (``repro.kernels.bulyan_select``) and the
model-axis-sharded distributed implementation (``repro.dist.robust``) reuse:
it is embarrassingly parallel over coordinates.

Note on the recursion depth: with theta = n - 2f iterations the last call to
A sees 2f + 1 vectors.  Krum's neighbour count n' - f - 2 can then reach 0
(for f <= 1), so we clamp it to >= 1 — matching the reference
implementation's behaviour (LPD-EPFL/bulyan).
"""
from __future__ import annotations

import itertools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import gars
from repro.core.types import AggResult


def _krum_pos(sub: jnp.ndarray, f: int, n_rem: int) -> jnp.ndarray:
    """Krum winner position on an (n_rem, n_rem) distance submatrix."""
    k = max(1, n_rem - f - 2)
    dm = sub + jnp.where(jnp.eye(n_rem, dtype=bool), jnp.inf, 0.0)
    snn = jnp.sort(dm, axis=1)[:, :k]
    return jnp.argmin(jnp.sum(snn, axis=1))


def _geomed_pos(sub: jnp.ndarray, n_rem: int) -> jnp.ndarray:
    dist = jnp.sqrt(jnp.maximum(sub, 0.0))
    return jnp.argmin(jnp.sum(dist, axis=1))


def _brute_pos(sub: jnp.ndarray, grads_rem: jnp.ndarray, f: int,
               n_rem: int) -> jnp.ndarray:
    """Brute on the remaining set: min-diameter subset of size n_rem - f,
    output = subset average; winner = remaining vector closest to it."""
    size = n_rem - f
    subsets = jnp.asarray(list(itertools.combinations(range(n_rem), size)))
    block = sub[subsets[:, :, None], subsets[:, None, :]]
    diam = jnp.max(block.reshape(subsets.shape[0], -1), axis=1)
    best = subsets[jnp.argmin(diam)]  # (size,)
    out = jnp.mean(grads_rem[best], axis=0)
    d2 = jnp.sum((grads_rem - out[None, :]) ** 2, axis=1)
    return jnp.argmin(d2)


def select_indices_from_dists(dist2: jnp.ndarray, f: int,
                              base: str = "krum") -> jnp.ndarray:
    """Phase 1 for distance-only bases (krum/geomed): (theta,) indices from
    the (n, n) squared-distance matrix alone.  This is what the distributed
    runtime uses — the matrix is tiny and replicated after an all-reduce of
    per-shard partial distances (see repro.dist.robust)."""
    n = dist2.shape[0]
    theta = n - 2 * f
    if n < 4 * f + 3:
        raise ValueError(f"bulyan requires n >= 4f+3, got n={n}, f={f}")
    if base not in ("krum", "geomed"):
        raise KeyError(f"distance-only selection needs krum/geomed, "
                       f"got {base!r}")
    rem = jnp.arange(n)
    picked = []
    for t in range(theta):
        n_rem = n - t
        sub = dist2[rem[:, None], rem[None, :]]
        pos = (_krum_pos(sub, f, n_rem) if base == "krum"
               else _geomed_pos(sub, n_rem))
        picked.append(rem[pos])
        rem = jnp.delete(rem, pos, assume_unique_indices=True)
    return jnp.stack(picked)


def select_indices(grads: jnp.ndarray, f: int, base: str = "krum",
                   dist2: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Phase 1: (theta,) original-worker indices chosen by the recursion.

    Unrolled in Python — theta = n - 2f is static and small (worker counts
    are <= a few dozen).  A *remaining-index array* maps static subset
    enumeration / static loop bounds onto the dynamically shrinking set.
    """
    n = grads.shape[0]
    theta = n - 2 * f
    if n < 4 * f + 3:
        raise ValueError(f"bulyan requires n >= 4f+3, got n={n}, f={f}")
    if dist2 is None:
        dist2 = gars.pairwise_sq_dists(grads)

    rem = jnp.arange(n)
    picked = []
    for t in range(theta):
        n_rem = n - t
        sub = dist2[rem[:, None], rem[None, :]]  # (n_rem, n_rem)
        if base == "krum":
            pos = _krum_pos(sub, f, n_rem)
        elif base == "geomed":
            pos = _geomed_pos(sub, n_rem)
        elif base == "average":
            out = jnp.mean(grads[rem], axis=0)
            pos = jnp.argmin(jnp.sum((grads[rem] - out[None, :]) ** 2, axis=1))
        elif base == "brute":
            pos = _brute_pos(sub, grads[rem], f, n_rem)
        else:
            raise KeyError(f"unsupported bulyan base {base!r}")
        picked.append(rem[pos])
        rem = jnp.delete(rem, pos, assume_unique_indices=True)
    return jnp.stack(picked)


def coordinate_phase(selected: jnp.ndarray, f: int) -> jnp.ndarray:
    """Phase 2 on a (theta, d) stack: per-coordinate average of the beta
    values closest to the coordinate-wise median.

    Key structural fact (reused by the Pallas kernel): after sorting each
    coordinate's theta values, the beta values closest to the median form a
    *contiguous window* of the sorted order.  We therefore sort once and
    scan the theta - beta + 1 candidate windows via cumulative sums — no
    second sort / argsort.
    """
    theta = selected.shape[0]
    beta = theta - 2 * f
    if beta < 1:
        raise ValueError(
            f"beta = theta - 2f must be >= 1 (theta={theta}, f={f})")
    s = jnp.sort(selected, axis=0)  # (theta, d)
    med = s[(theta - 1) // 2]       # 1-D medoid: lower-middle of sorted vals
    if beta == theta:
        return jnp.mean(s, axis=0)
    absdev = jnp.abs(s - med[None, :])
    zeros = jnp.zeros_like(s[:1])
    cd = jnp.concatenate([zeros, jnp.cumsum(absdev, axis=0)], axis=0)
    cv = jnp.concatenate([zeros, jnp.cumsum(s, axis=0)], axis=0)
    n_win = theta - beta + 1
    win_dev = cd[beta:] - cd[:n_win]  # (n_win, d): sum |x - med| per window
    win_sum = cv[beta:] - cv[:n_win]  # (n_win, d): sum x per window
    w = jnp.argmin(win_dev, axis=0)   # (d,)
    best = jnp.take_along_axis(win_sum, w[None, :], axis=0)[0]
    return best / beta


def coordinate_phase_ref(selected: jnp.ndarray, f: int) -> jnp.ndarray:
    """Literal transcription of the paper's formula (argsort of |x - med|);
    independent oracle for the windowed implementation and the Pallas
    kernel.  Ties (measure-zero for float inputs) may resolve differently.
    """
    theta = selected.shape[0]
    beta = theta - 2 * f
    s = jnp.sort(selected, axis=0)
    med = s[(theta - 1) // 2]
    dist = jnp.abs(selected - med[None, :])
    order = jnp.argsort(dist, axis=0)[:beta]  # (beta, d)
    closest = jnp.take_along_axis(selected, order, axis=0)
    return jnp.mean(closest, axis=0)


def make_bulyan(base: str = "krum",
                coordinate_impl: Optional[Callable] = None):
    """Build Bulyan(base) as a standard GAR callable."""
    cp = coordinate_impl or coordinate_phase

    def bulyan(grads: jnp.ndarray, f: int) -> AggResult:
        n = grads.shape[0]
        idx = select_indices(grads, f, base=base)
        selected = grads[idx]  # (theta, d)
        agg = cp(selected, f)
        sel = jnp.zeros((n,), grads.dtype).at[idx].set(1.0)
        return AggResult(agg, sel, jnp.zeros((n,), grads.dtype))

    bulyan.__name__ = f"bulyan_{base}"
    return bulyan
