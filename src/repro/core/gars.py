"""Gradient aggregation rules (GARs) from the Bulyan paper plus standard
baselines.

Implemented (paper §2.3): ``average``, ``krum``, ``geomed`` (the Medoid),
``brute``.  Extras beyond the paper, used as additional baselines in the
benchmarks: ``multikrum``, ``cwmed`` (coordinate-wise median),
``trimmed_mean``, ``centered_clip``.

All rules are pure-jnp, jit-compatible, and take ``(grads: (n, d), f)`` with
static ``n``/``f``.  Selection-style rules also expose a ``*_select`` helper
returning the chosen index given a pairwise squared-distance matrix and a
validity mask — these helpers are what Bulyan's recursive phase consumes
(see ``repro.core.bulyan``) and what the distributed runtime reuses on
all-reduced partial distance matrices (see ``repro.dist.robust``).

Each rule registers itself with the unified registry (``repro.agg``) via
``@register_rule``; ``get_gar`` / ``quorum`` below are thin wrappers over
``repro.agg.registry.resolve_rule`` kept for the historic import path.
"""
from __future__ import annotations

import itertools
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.agg.registry import RULES, register_rule, resolve_rule
from repro.agg.registry import quorum as _registry_quorum
from repro.core.types import AggResult

_INF = jnp.inf


# ---------------------------------------------------------------------------
# distance plumbing
# ---------------------------------------------------------------------------

def pairwise_sq_dists(grads: jnp.ndarray) -> jnp.ndarray:
    """(n, d) -> (n, n) matrix of squared euclidean distances.

    Uses the Gram-matrix decomposition ``|x|^2 + |y|^2 - 2<x,y>`` so the bulk
    of the work is a single MXU-friendly matmul.  The Pallas kernel in
    ``repro.kernels.pairwise_gram`` implements the same contraction with
    explicit d-tiling; this jnp version is its oracle.
    """
    sq = jnp.sum(grads * grads, axis=-1)
    gram = grads @ grads.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    d2 = jnp.maximum(d2, 0.0)  # numerical floor
    return d2 * (1.0 - jnp.eye(grads.shape[0], dtype=grads.dtype))


def _masked(dist2: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Set rows/cols of excluded workers (mask == False) to +inf, and the
    diagonal to +inf so "self" never counts as a neighbour."""
    n = dist2.shape[0]
    valid = mask[:, None] & mask[None, :]
    off_diag = ~jnp.eye(n, dtype=bool)
    return jnp.where(valid & off_diag, dist2, _INF)


# ---------------------------------------------------------------------------
# selection helpers (used standalone and inside Bulyan's recursion)
# ---------------------------------------------------------------------------

def krum_scores(dist2: jnp.ndarray, mask: jnp.ndarray, f: int,
                n_remaining: int) -> jnp.ndarray:
    """Krum score: sum of squared distances to the ``n_remaining - f - 2``
    closest *remaining* vectors.  ``n_remaining`` must be static."""
    k = n_remaining - f - 2
    if k < 1:
        raise ValueError(
            f"krum needs n >= f + 3 per use (n={n_remaining}, f={f})")
    dm = _masked(dist2, mask)
    # ascending sort puts the masked +inf entries last
    snn = jnp.sort(dm, axis=1)[:, :k]
    scores = jnp.sum(snn, axis=1)
    return jnp.where(mask, scores, _INF)


def krum_select(dist2: jnp.ndarray, mask: jnp.ndarray, f: int,
                n_remaining: int) -> jnp.ndarray:
    return jnp.argmin(krum_scores(dist2, mask, f, n_remaining))


def geomed_scores(dist2: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Medoid score: sum of (non-squared) distances to remaining vectors."""
    dm = _masked(dist2, mask)
    dist = jnp.sqrt(jnp.where(jnp.isinf(dm), 0.0, dm))
    scores = jnp.sum(dist, axis=1)
    return jnp.where(mask, scores, _INF)


def geomed_select(dist2: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    # argmin returns the smallest index among ties — matching the paper's
    # "Medoid ... with the smallest index".
    return jnp.argmin(geomed_scores(dist2, mask))


def _subsets(n: int, size: int):
    return list(itertools.combinations(range(n), size))


def brute_subset_diameters(dist2: jnp.ndarray, n: int, f: int) -> jnp.ndarray:
    """Diameter (max pairwise squared distance) of every (n-f)-subset.

    Enumerated at trace time — Brute is only practical for small n
    (paper §2.3.1), and we use it exactly as the paper does: as a small-n
    benchmark.
    """
    subsets = _subsets(n, n - f)
    idx = jnp.asarray(subsets)  # (S, n-f)
    sub = dist2[idx[:, :, None], idx[:, None, :]]  # (S, n-f, n-f)
    return jnp.max(sub.reshape(len(subsets), -1), axis=1)


# ---------------------------------------------------------------------------
# the GARs themselves
# ---------------------------------------------------------------------------

@register_rule("average", min_n=lambda f: 1, byzantine_resilient=False,
               invariants=("finite", "hull", "convex"),
               doc="arithmetic mean (not Byzantine-resilient)")
def average(grads: jnp.ndarray, f: int = 0) -> AggResult:
    """Arithmetic mean — the non-robust reference (paper Fig. 2/3)."""
    n = grads.shape[0]
    w = jnp.full((n,), 1.0 / n, dtype=grads.dtype)
    return AggResult(jnp.mean(grads, axis=0), w, jnp.zeros((n,), grads.dtype))


@register_rule("krum", min_n=lambda f: 2 * f + 3,
               invariants=("finite", "hull", "convex"),
               doc="Blanchard et al. 2017")
def krum(grads: jnp.ndarray, f: int) -> AggResult:
    """Krum (Blanchard et al., 2017): output the vector with the smallest
    sum of squared distances to its n - f - 2 nearest neighbours."""
    n = grads.shape[0]
    if n < 2 * f + 3:
        raise ValueError(f"krum requires n >= 2f+3, got n={n}, f={f}")
    dist2 = pairwise_sq_dists(grads)
    mask = jnp.ones((n,), dtype=bool)
    scores = krum_scores(dist2, mask, f, n)
    i = jnp.argmin(scores)
    sel = jax.nn.one_hot(i, n, dtype=grads.dtype)
    return AggResult(grads[i], sel, scores)


@register_rule("multikrum", min_n=lambda f: 2 * f + 3,
               invariants=("finite", "hull", "convex"),
               doc="average of m best Krum scores")
def multikrum(grads: jnp.ndarray, f: int, m: Optional[int] = None) -> AggResult:
    """Multi-Krum: average of the m best-scored vectors (m = n - f - 2 by
    default).  Beyond-paper baseline (from the Krum paper)."""
    n = grads.shape[0]
    if m is None:
        m = max(1, n - f - 2)
    dist2 = pairwise_sq_dists(grads)
    scores = krum_scores(dist2, jnp.ones((n,), bool), f, n)
    _, top = jax.lax.top_k(-scores, m)
    sel = jnp.zeros((n,), grads.dtype)
    sel = sel.at[top].set(1.0 / m)
    return AggResult(sel @ grads, sel, scores)


@register_rule("geomed", min_n=lambda f: 2 * f + 1,
               invariants=("finite", "hull", "convex"),
               doc="medoid with smallest index")
def geomed(grads: jnp.ndarray, f: int = 0) -> AggResult:
    """GeoMed — the Medoid with the smallest index (paper §2.3.3)."""
    n = grads.shape[0]
    dist2 = pairwise_sq_dists(grads)
    scores = geomed_scores(dist2, jnp.ones((n,), bool))
    i = jnp.argmin(scores)
    sel = jax.nn.one_hot(i, n, dtype=grads.dtype)
    return AggResult(grads[i], sel, scores)


@register_rule("brute", min_n=lambda f: 2 * f + 1,
               invariants=("finite", "hull", "convex"),
               doc="min-diameter subset average (small n only)")
def brute(grads: jnp.ndarray, f: int) -> AggResult:
    """Brute (paper §2.3.1): average of the most clumped (n-f)-subset,
    i.e. the subset minimizing its max pairwise distance."""
    n = grads.shape[0]
    if n < 2 * f + 1:
        raise ValueError(f"brute requires n >= 2f+1, got n={n}, f={f}")
    dist2 = pairwise_sq_dists(grads)
    diam = brute_subset_diameters(dist2, n, f)
    best = jnp.argmin(diam)
    idx = jnp.asarray(_subsets(n, n - f))  # (S, n-f)
    chosen = idx[best]  # (n-f,)
    sel = jnp.zeros((n,), grads.dtype).at[chosen].set(1.0 / (n - f))
    agg = sel @ grads
    # per-worker score: diameter of the best subset containing the worker
    member = jnp.zeros((len(idx), n), bool).at[
        jnp.arange(len(idx))[:, None], idx].set(True)
    scores = jnp.min(jnp.where(member, diam[:, None], _INF), axis=0)
    return AggResult(agg, sel, scores)


@register_rule("cwmed", min_n=lambda f: 2 * f + 1,
               invariants=("finite", "hull", "trimmed"),
               doc="coordinate-wise median")
def cwmed(grads: jnp.ndarray, f: int = 0) -> AggResult:
    """Coordinate-wise median (Yin et al., 2018) — beyond-paper baseline."""
    n = grads.shape[0]
    agg = jnp.median(grads, axis=0)
    return AggResult(agg, jnp.full((n,), 1.0 / n, grads.dtype),
                     jnp.zeros((n,), grads.dtype))


@register_rule("trimmed_mean", min_n=lambda f: 2 * f + 1,
               invariants=("finite", "hull", "trimmed"),
               doc="coordinate-wise trimmed mean")
def trimmed_mean(grads: jnp.ndarray, f: int) -> AggResult:
    """Coordinate-wise f-trimmed mean (Yin et al., 2018) — beyond-paper."""
    n = grads.shape[0]
    if n <= 2 * f:
        raise ValueError(f"trimmed_mean requires n > 2f, got n={n}, f={f}")
    s = jnp.sort(grads, axis=0)
    agg = jnp.mean(s[f:n - f], axis=0)
    return AggResult(agg, jnp.full((n,), 1.0 / n, grads.dtype),
                     jnp.zeros((n,), grads.dtype))


@register_rule("centered_clip", min_n=lambda f: 2 * f + 1,
               invariants=("finite", "hull"),
               doc="iterative centered clipping")
def centered_clip(grads: jnp.ndarray, f: int, tau: float = 10.0,
                  iters: int = 3) -> AggResult:
    """Centered clipping (Karimireddy et al., 2021) — beyond-paper baseline.

    Iteratively clips worker deviations from a running center to radius tau.
    """
    n = grads.shape[0]
    v = jnp.mean(grads, axis=0)

    def body(_, v):
        delta = grads - v[None, :]
        norm = jnp.linalg.norm(delta, axis=1, keepdims=True)
        scale = jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-12))
        return v + jnp.mean(delta * scale, axis=0)

    v = jax.lax.fori_loop(0, iters, body, v)
    return AggResult(v, jnp.full((n,), 1.0 / n, grads.dtype),
                     jnp.zeros((n,), grads.dtype))


# ---------------------------------------------------------------------------
# registry (now a view onto repro.agg.registry)
# ---------------------------------------------------------------------------

#: historic alias — the live rule table of ``repro.agg.registry``; entries
#: are ``AggregatorRule`` records whose ``.fn`` property preserves the old
#: ``GarSpec.fn`` access pattern.
REGISTRY = RULES


def get_gar(name: str):
    """Resolve a GAR by name through the unified registry.

    ``bulyan-<base>`` builds Bulyan(base); ``buffered-<base>`` resolves
    to the *stateful* dense fn ``(grads, f, state) -> (AggResult, state)``
    (see ``repro.agg``).
    """
    return resolve_rule(name).dense_fn


def quorum(name: str, f: int) -> int:
    """Minimal n for a rule at a given f (delegates to ``repro.agg``)."""
    return _registry_quorum(name, f)
