"""Optimizers + the paper's fading learning-rate schedule.

Self-contained (no optax): each optimizer is an ``Optimizer(init, update)``
pair over parameter pytrees.  ``update(grads, state, params) ->
(new_params, new_state)``; the learning rate is a schedule ``step -> lr``
evaluated in-graph (works under jit with a traced step).

The paper (§5.1) uses plain SGD with eta(epoch) = eta0 * r / (epoch + r).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def _as_schedule(lr: Union[float, Schedule]) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def fading_lr(eta0: float, r: float) -> Schedule:
    """Paper §5.1: eta(t) = eta0 * r / (t + r)."""
    return lambda step: jnp.asarray(eta0 * r, jnp.float32) / (step + r)


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, step) -> (params, state)


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def sgd(lr: Union[float, Schedule]) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        eta = sched(state["step"])
        new = _tmap(lambda p, g: (p.astype(jnp.float32)
                                  - eta * g.astype(jnp.float32)
                                  ).astype(p.dtype), params, grads)
        return new, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(lr: Union[float, Schedule], beta: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params):
        eta = sched(state["step"])
        m = _tmap(lambda m, g: beta * m + g.astype(jnp.float32),
                  state["m"], grads)
        new = _tmap(lambda p, m: (p.astype(jnp.float32) - eta * m
                                  ).astype(p.dtype), params, m)
        return new, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr: Union[float, Schedule], b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params):
        t = state["step"] + 1
        eta = sched(state["step"])
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v, g: b2 * v
                  + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * step).astype(p.dtype)

        new = _tmap(upd, params, m, v)
        return new, {"step": t, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam,
            "adamw": adamw}[name](lr, **kw)
