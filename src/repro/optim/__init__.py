from repro.optim.optimizers import (Optimizer, adam, adamw, fading_lr,
                                    get_optimizer, momentum, sgd)

__all__ = ["Optimizer", "adam", "adamw", "fading_lr", "get_optimizer",
           "momentum", "sgd"]
