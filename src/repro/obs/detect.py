"""Host-side attack detectors over drained aggregation forensics.

The paper's attack works by steering selection: a crafted Byzantine row
wins Krum's score every step, so the selection distribution collapses
onto the attacker while honest workers starve.  These detectors turn the
drained :class:`~repro.obs.buffer.MetricsBuffer` (``repro.obs.buffer
.drain``) into the three live signals an operator watches:

* **selection entropy** — normalized Shannon entropy of the per-worker
  selection frequency; ~1 for a healthy rotating committee, collapsing
  toward 0 when one row monopolizes selection (the attack signature);
* **suspicion ranking** — per-worker blend of distance-to-aggregate and
  selection starvation; under a *defended* attack the Byzantine rows
  rank most suspect;
* **margin trajectory** — the empirical ε-poisoning-leeway proxy
  ``1 - agg_dev / spread`` per record: how much of the honest spread the
  aggregate ceded to drift.

Everything here is plain numpy on drained host data — nothing is traced.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import numpy as np

__all__ = ["margin_trajectory", "selection_collapsed",
           "selection_entropy", "suspicion_scores"]

_EPS = 1e-12


def selection_entropy(freq: np.ndarray) -> float:
    """Normalized Shannon entropy of a selection-frequency vector.

    Args:
      freq: ``(n,)`` nonnegative per-worker selection shares (need not
        be normalized; ``drain()['selection_frequency']`` already is).

    Returns:
      ``H(p) / log(n)`` in ``[0, 1]`` — 1 for uniform selection, 0 when
      a single worker takes everything (and 0 for empty/zero input).
    """
    p = np.asarray(freq, np.float64).ravel()
    total = p.sum()
    if p.size <= 1 or total <= 0:
        return 0.0
    p = p / total
    h = -np.sum(p * np.log(np.maximum(p, _EPS)))
    return float(h / np.log(p.size))


def selection_collapsed(freq: np.ndarray, threshold: float = 0.5) -> bool:
    """Flag the paper's selection-monopoly signature.

    Args:
      freq: ``(n,)`` per-worker selection shares.
      threshold: entropy level below which selection counts as
        collapsed (0.5 ~ "half the committee's diversity lost").

    Returns:
      True when :func:`selection_entropy` fell below ``threshold``.
    """
    return selection_entropy(freq) < threshold


def suspicion_scores(records: Sequence[Dict[str, Any]],
                     freq: np.ndarray) -> np.ndarray:
    """Per-worker suspicion in ``[0, 1]`` from a drained run.

    Blends two independent signals, each normalized to ``[0, 1]``:
    the run-mean distance-to-aggregate (an outlier submission pattern)
    and selection starvation ``1 - freq / max(freq)`` (the defense
    refusing a worker).  Under a defended attack both point at the
    Byzantine rows, so sorting descending ranks them first.

    Args:
      records: chronological record dicts from ``drain()['records']``
        (each carrying a ``(n,)`` ``dist_to_agg``).
      freq: ``(n,)`` per-worker selection shares
        (``drain()['selection_frequency']``).

    Returns:
      ``(n,)`` fp64 suspicion scores (empty array for an empty run).
    """
    freq = np.asarray(freq, np.float64)
    if not records:
        return np.zeros_like(freq)
    dist = np.mean([np.asarray(r["dist_to_agg"], np.float64)
                    for r in records], axis=0)
    dist_n = dist / max(float(dist.max()), _EPS)
    starve = 1.0 - freq / max(float(freq.max()), _EPS)
    return 0.5 * (dist_n + starve)


def margin_trajectory(records: Sequence[Dict[str, Any]]) -> np.ndarray:
    """Empirical ε-leeway proxy per recorded step.

    ``1 - agg_dev / spread``: 1 when the aggregate sits on the honest
    mean, 0 when it drifted a full worker-spread away — the measurable
    shadow of the paper's poisoning-leeway ε.  Clipped below at -1 so a
    catastrophically steered aggregate stays plottable.

    Args:
      records: chronological record dicts from ``drain()['records']``.

    Returns:
      ``(len(records),)`` fp64 margins.
    """
    out = []
    for r in records:
        spread = float(np.asarray(r["spread"]))
        dev = float(np.asarray(r["agg_dev"]))
        out.append(max(1.0 - dev / max(spread, _EPS), -1.0))
    return np.asarray(out, np.float64)
