"""JSONL / CSV exporters for drained telemetry and metric histories.

Thin, dependency-free writers shared by the trainers' ``telemetry()``
drains, the serving engine, ``scripts/obs_report.py`` and the benchmark
artifact writer.  Numpy scalars/arrays are converted to plain python
(lists) before serialization, so every artifact is readable without
numpy.
"""
from __future__ import annotations

import csv
import json
from typing import Any, Dict, Iterable, List, Sequence

import numpy as np

__all__ = ["read_jsonl", "to_jsonable", "write_csv", "write_jsonl"]


def to_jsonable(obj: Any) -> Any:
    """Recursively convert numpy/jax containers to JSON-native types.

    Args:
      obj: any nesting of dict/list/tuple over scalars, numpy scalars
        and arrays (jax arrays convert through ``np.asarray``).

    Returns:
      The same structure with arrays as lists and numpy scalars as
      python ints/floats/bools.
    """
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.generic,)):
        return obj.item()
    if hasattr(obj, "__array__") and not isinstance(obj, (str, bytes)):
        return np.asarray(obj).tolist()
    return obj


def write_jsonl(path, rows: Iterable[Dict[str, Any]]) -> int:
    """Write rows as one JSON object per line.

    Args:
      path: destination file path (overwritten).
      rows: iterable of dict rows (numpy content allowed).

    Returns:
      Number of rows written.
    """
    count = 0
    with open(path, "w") as fh:
        for row in rows:
            fh.write(json.dumps(to_jsonable(row)) + "\n")
            count += 1
    return count


def read_jsonl(path) -> List[Dict[str, Any]]:
    """Read back a JSONL file written by :func:`write_jsonl`.

    Args:
      path: source file path.

    Returns:
      List of dict rows (blank lines skipped).
    """
    rows: List[Dict[str, Any]] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def write_csv(path, rows: Sequence[Dict[str, Any]],
              fieldnames: "Sequence[str] | None" = None) -> int:
    """Write dict rows as CSV with a header line.

    Args:
      path: destination file path (overwritten).
      rows: dict rows; nested values are JSON-encoded into their cell.
      fieldnames: explicit column order (default: keys of the first
        row, in insertion order; extra keys in later rows error).

    Returns:
      Number of data rows written.
    """
    rows = list(rows)
    if fieldnames is None:
        fieldnames = list(rows[0].keys()) if rows else []
    with open(path, "w", newline="") as fh:
        writer = csv.DictWriter(fh, fieldnames=list(fieldnames))
        writer.writeheader()
        for row in rows:
            flat = {}
            for k in fieldnames:
                v = to_jsonable(row.get(k))
                flat[k] = (json.dumps(v)
                           if isinstance(v, (dict, list)) else v)
            writer.writerow(flat)
    return len(rows)
