"""Observability: aggregation forensics, metrics schema, tracing, export.

The telemetry layer of the Byzantine runtime (see
docs/observability.md).  Four pieces, all importable from here:

* ``repro.obs.buffer`` — the jit-compatible :class:`MetricsBuffer`
  forensics ring carried in ``AggState.obs`` and its host-side
  :func:`drain`;
* ``repro.obs.forensics`` — the ``obs-<base>`` registry family
  (:func:`make_obs`) recording one :class:`AggDiagnostics` row per
  aggregation call with the base rule's data path bitwise untouched;
* ``repro.obs.detect`` — host-side attack detectors (selection-entropy
  collapse, suspicion ranking, ε-margin trajectory);
* ``repro.obs.schema`` / ``repro.obs.trace`` / ``repro.obs.export`` —
  the shared train-metrics schema, named-scope + span-timer tracing
  hooks, and JSONL/CSV writers.

Enable end to end with ``AggSpec(..., telemetry=True)`` — every train /
async / serve step then aggregates through ``spec.effective_gar``
(``obs-<gar>``) and the carried state's ring is drained by the
trainers' / engine's ``telemetry()`` methods.
"""
from repro.obs.buffer import (DEFAULT_OBS_CAPACITY, AggDiagnostics,
                              MetricsBuffer, drain, init_metrics_buffer,
                              push_record)
from repro.obs.detect import (margin_trajectory, selection_collapsed,
                              selection_entropy, suspicion_scores)
from repro.obs.export import (read_jsonl, to_jsonable, write_csv,
                              write_jsonl)
from repro.obs.forensics import (dense_diagnostics, make_obs, obs_name,
                                 tree_diagnostics)
from repro.obs.schema import (METRIC_SCHEMA, async_extras, core_metrics,
                              global_norm, selection_weight)
from repro.obs.trace import (EVENT_FIELDS, SpanTimer, named_span,
                             span_event)

__all__ = [
    "AggDiagnostics",
    "DEFAULT_OBS_CAPACITY",
    "EVENT_FIELDS",
    "METRIC_SCHEMA",
    "MetricsBuffer",
    "SpanTimer",
    "async_extras",
    "core_metrics",
    "dense_diagnostics",
    "drain",
    "global_norm",
    "init_metrics_buffer",
    "make_obs",
    "margin_trajectory",
    "named_span",
    "obs_name",
    "push_record",
    "read_jsonl",
    "selection_collapsed",
    "selection_entropy",
    "selection_weight",
    "span_event",
    "suspicion_scores",
    "to_jsonable",
    "tree_diagnostics",
    "write_csv",
    "write_jsonl",
]
