"""The one train-step metrics schema every execution path shares.

Before this module, four near-duplicate ``metrics = {...}`` dicts lived
in ``repro.training.trainer`` (sync + async flat paths),
``repro.dist.train`` and ``repro.dist.async_train`` — and their key sets
had already drifted (``staleness_excess`` existed only on the sharded
async path, ``step_scale`` only on reputation-carrying ones).  All four
now assemble their dicts through :func:`core_metrics` /
:func:`async_extras`, so a metric name or dtype can only change here,
and :data:`METRIC_SCHEMA` is the canonical catalog the exporters, the
dashboard (``scripts/obs_report.py``) and the cross-path consistency
test validate against.

Every builder keeps the exact expressions the paths used before the
unification — values are bitwise what they were, only the assembly is
shared.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["METRIC_SCHEMA", "async_extras", "core_metrics",
           "global_norm", "selection_weight"]

#: canonical metric catalog: name -> (paths, description).  ``paths`` is
#: a ``/``-joined subset of {sync, async} x {flat, dist}; ``all`` means
#: every train path emits it.
METRIC_SCHEMA: Dict[str, tuple] = {
    "loss": ("all", "mean honest-worker training loss at step start"),
    "byz_weight": ("all", "total selection weight landing on the "
                          "injected Byzantine rows (0 when f == 0)"),
    "agg_dev": ("all", "L2 distance between the emitted aggregate and "
                       "the honest mean (the poisoning-leeway probe)"),
    "grad_norm": ("all", "global L2 norm of the emitted aggregate"),
    "step_scale": ("reputation", "scalar step-size multiplier from "
                                 "carried trust (reputation-* rules "
                                 "with spec.rep_lr set)"),
    "staleness_mean": ("async", "mean per-worker slot age at "
                                "aggregation time"),
    "staleness_max": ("async", "oldest slot age in the aggregated bus"),
    "staleness_excess": ("async", "max overshoot beyond the bounded-"
                                  "staleness bound tau (0 = bound held)"),
    "delivered": ("async", "worker slots refreshed this step"),
}


def global_norm(tree) -> jnp.ndarray:
    """Global L2 norm of a pytree, accumulated per leaf in fp32.

    One squared-sum contraction per leaf — never materializes a flat
    vector, so leaf shardings survive (the sharded engine's invariant).

    Args:
      tree: any pytree of arrays.

    Returns:
      fp32 scalar ``sqrt(sum_leaves sum(x^2))``.
    """
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        x = leaf.astype(jnp.float32)
        total = total + jnp.sum(x * x)
    return jnp.sqrt(total)


def selection_weight(selected: jnp.ndarray, n_honest: int) -> jnp.ndarray:
    """Total selection weight on the Byzantine rows (``byz_weight``).

    The stacked protocol appends the ``f`` injected rows after the
    ``n_honest`` honest ones, so their selection mass is the tail sum.

    Args:
      selected: ``(n,)`` per-worker selection mask/weights from the
        rule's result.
      n_honest: honest row count (static).

    Returns:
      fp32-compatible scalar — the tail sum when Byzantine rows exist,
      else a float32 zero (the historic both-paths convention).
    """
    if selected.shape[0] > n_honest:
        return jnp.sum(selected[n_honest:])
    return jnp.zeros((), jnp.float32)


def core_metrics(*, loss, grad_norm, agg_dev, byz_weight,
                 step_scale: Optional[jnp.ndarray] = None) -> Dict:
    """Assemble the four-key core metrics dict every train path emits.

    Args:
      loss: scalar training loss.
      grad_norm: scalar aggregate norm (``global_norm`` on the tree
        paths, ``jnp.linalg.norm`` on the flat ones).
      agg_dev: scalar aggregate-to-honest-mean deviation.
      byz_weight: scalar Byzantine selection mass
        (:func:`selection_weight`).
      step_scale: optional reputation step-size multiplier; included
        only when the path carries reputation (``None`` omits the key,
        preserving each path's historic key set).

    Returns:
      Dict with the canonical :data:`METRIC_SCHEMA` names.
    """
    metrics = {"loss": loss, "byz_weight": byz_weight,
               "agg_dev": agg_dev, "grad_norm": grad_norm}
    if step_scale is not None:
        metrics["step_scale"] = step_scale
    assert set(metrics) <= set(METRIC_SCHEMA)
    return metrics


def async_extras(staleness: jnp.ndarray, excess: jnp.ndarray,
                 deliver: jnp.ndarray) -> Dict:
    """The four extra metrics the asynchronous paths add.

    Args:
      staleness: ``(n,)`` int per-worker slot age ``t - bus.versions``.
      excess: ``(n,)`` int per-worker overshoot of the bounded-staleness
        bound (``repro.dist.async_train.staleness_excess``).
      deliver: ``(n,)`` bool delivery mask of this step.

    Returns:
      Dict with ``staleness_mean`` / ``staleness_max`` /
      ``staleness_excess`` / ``delivered``, all fp32 scalars (the
      historic expressions, now shared by the flat and sharded async
      steps — ``staleness_excess`` used to exist only on the sharded
      one).
    """
    metrics = {
        "staleness_mean": jnp.mean(staleness.astype(jnp.float32)),
        "staleness_max": jnp.max(staleness).astype(jnp.float32),
        "staleness_excess": jnp.max(excess).astype(jnp.float32),
        "delivered": jnp.sum(deliver).astype(jnp.float32),
    }
    assert set(metrics) <= set(METRIC_SCHEMA)
    return metrics
