"""Tracing hooks: in-graph named scopes + a host-side span timer.

Two complementary layers share one event schema (:data:`EVENT_FIELDS`):

* :func:`named_span` — a zero-cost ``jax.named_scope`` wrapper the hot
  paths wear around their phases (``agg/gram``, ``agg/select``,
  ``agg/coordinate``, ``serve/verify``, ``kernel/fused``), so profiler
  timelines (``jax.profiler.trace``) and HLO dumps carry readable phase
  names.  Metadata-only: it never changes the computation.
* :class:`SpanTimer` — a host-side wall-clock timer whose
  ``with timer.span("name")`` blocks become event rows; benchmarks and
  the serving engine export them as JSONL with the same schema the
  roofline/p99 rows use, so one tooling path reads both.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, List

import jax

__all__ = ["EVENT_FIELDS", "SpanTimer", "named_span", "span_event"]

#: the shared event schema: every exported timing row carries exactly
#: these keys (``meta`` is a free-form dict — backend, shape, seed, ...)
EVENT_FIELDS = ("name", "us", "meta")


def named_span(name: str):
    """Profiler/HLO phase annotation (``jax.named_scope`` passthrough).

    Purely metadata: operations traced under the returned context keep
    bitwise-identical lowering, they just carry ``name`` in profiler
    timelines and HLO op names.

    Args:
      name: phase label, conventionally ``layer/phase`` (e.g.
        ``"agg/gram"``).

    Returns:
      A context manager usable inside or outside traced code.
    """
    return jax.named_scope(name)


def span_event(name: str, us: float, **meta: Any) -> Dict[str, Any]:
    """One timing event row in the shared schema.

    Args:
      name: event label (phase or benchmark row name).
      us: duration in microseconds.
      **meta: free-form metadata (backend, n, d, seed, ...).

    Returns:
      Dict with exactly :data:`EVENT_FIELDS`.
    """
    return {"name": name, "us": float(us), "meta": dict(meta)}


class SpanTimer:
    """Host-side wall-clock span collector with JSONL export.

    Usage::

        timer = SpanTimer()
        with timer.span("serve/decode_step", batch=8):
            engine.step()
        timer.export_jsonl("events.jsonl")

    Spans time host-observed wall clock (``time.perf_counter``) — call
    ``jax.block_until_ready`` inside the block when device work must be
    included.  The collected rows follow :data:`EVENT_FIELDS`.
    """

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []

    @contextlib.contextmanager
    def span(self, name: str, **meta: Any):
        """Time one ``with`` block as an event row.

        Args:
          name: event label.
          **meta: free-form metadata attached to the row.

        Returns:
          A context manager appending one :func:`span_event` row on
          exit (also on exception, so partial runs keep their timeline).
        """
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            us = (time.perf_counter() - t0) * 1e6
            self.events.append(span_event(name, us, **meta))

    def export_jsonl(self, path) -> int:
        """Write the collected events as one JSON object per line.

        Args:
          path: destination file path (overwritten).

        Returns:
          Number of event rows written.
        """
        with open(path, "w") as fh:
            for ev in self.events:
                fh.write(json.dumps(ev) + "\n")
        return len(self.events)
