"""Aggregation forensics: the ``obs-<base>`` telemetry rule family.

The paper's whole argument is about what robust aggregation *silently
does* — which workers Krum selects, how far the aggregate drifts inside
the ε-poisoning leeway — and every one of those quantities is already
computed (or one reduction away) inside the rule application.
``obs-<base>`` wraps **any** registered rule through the unchanged
registry (``resolve_rule("obs-krum")``, nesting outside ``stale-`` /
``buffered-`` / ``reputation-`` / ``fused-`` / ``bulyan-`` composites)
and records one :class:`~repro.obs.buffer.AggDiagnostics` row per call
into the :class:`~repro.obs.buffer.MetricsBuffer` ring carried in
``AggState.obs``.

The contract that makes telemetry free to enable: the wrapper **never
touches the data path**.  The base rule runs on the untouched stack and
its result is returned bitwise-unchanged; the wrapper only *reads* the
stack and the result to assemble the record.  Quorum (``min_n``),
resilience and declared invariants are the base's own.

The per-coordinate reductions (distance-to-aggregate, trimmed-range
fraction) run on a **fixed-size coordinate sketch** — at most
:data:`OBS_SKETCH` deterministically-placed coordinates of the stack,
with norms scaled by ``sqrt(d / S)`` back to full-space magnitude — so
the telemetry cost is bounded by the committee size, not the model
size.  Order statistics on the sketch use a rank-count formulation
(one broadcast compare over the ``(n, n, S)`` cube) instead of a sort:
XLA's variadic sort on a thin worker axis costs more than the entire
instrumented train step on CPU.

Diagnostics derived on the host from the drained ring live in
``repro.obs.detect``; see docs/observability.md for the full catalog.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.agg.registry import AggregatorRule, TreeContext
from repro.obs.buffer import (DEFAULT_OBS_CAPACITY, AggDiagnostics,
                              push_record)

__all__ = ["OBS_SKETCH", "dense_diagnostics", "make_obs", "obs_name",
           "tree_diagnostics"]

#: max coordinates the forensic reductions touch per record; the dense
#: path samples this many across evenly spaced contiguous blocks, the
#: tree path apportions it over the leaves
OBS_SKETCH = 512

#: evenly spaced contiguous blocks the dense sketch is drawn from
_SKETCH_BLOCKS = 16


def obs_name(gar: str) -> str:
    """The instrumented name of a GAR (idempotent).

    Args:
      gar: any name ``resolve_rule`` accepts.

    Returns:
      ``"obs-<gar>"``, or ``gar`` unchanged when already instrumented.
    """
    return gar if gar.startswith("obs-") else "obs-" + gar


def _worker_snapshots(state, base: AggregatorRule,
                      n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(reputation, staleness) ``(n,)`` fp32 snapshots from the state.

    Branches on the base's *static* ``state_fields`` so the wrapper adds
    no pytree-dependent control flow.  The serving reputation layout
    ``(n, batch)`` is averaged over its trailing axes.
    """
    if "reputation" in base.state_fields:
        rep = state.reputation.astype(jnp.float32)
        if rep.ndim > 1:
            rep = jnp.mean(rep, axis=tuple(range(1, rep.ndim)))
    else:
        rep = jnp.ones((n,), jnp.float32)
    if "bus" in base.state_fields:
        stale = jnp.maximum(
            state.step - state.bus.versions, 0).astype(jnp.float32)
    else:
        stale = jnp.zeros((n,), jnp.float32)
    return rep, stale


def _sketch_dense(flat: jnp.ndarray) -> jnp.ndarray:
    """Deterministic ``(n, S <= OBS_SKETCH)`` sketch of a flat stack.

    Evenly spaced contiguous blocks: representative across the
    coordinate space (layers, in a flattened param tree) while reading
    only ``O(n * S)`` memory — a strided gather would touch the whole
    array.
    """
    d = flat.shape[1]
    if d <= OBS_SKETCH:
        return flat
    blk = OBS_SKETCH // _SKETCH_BLOCKS
    starts = [round(i * (d - blk) / (_SKETCH_BLOCKS - 1))
              for i in range(_SKETCH_BLOCKS)]
    return jnp.concatenate([flat[:, s:s + blk] for s in starts], axis=1)


def _trim_bounds(g: jnp.ndarray, f: int):
    """Per-coordinate f-trimmed range of a ``(n, S)`` fp32 sketch.

    Rank-count order statistics (ties broken by row index, matching a
    stable sort): one broadcast compare over ``(n, n, S)`` — far cheaper
    than XLA's thin-axis sort for the committee sizes in play.
    """
    n = g.shape[0]
    f_eff = min(max(int(f), 0), (n - 1) // 2)
    lt = g[None, :, :] < g[:, None, :]
    tie = g[None, :, :] == g[:, None, :]
    idx = jnp.arange(n)
    rank = jnp.sum(
        lt | (tie & (idx[None, :, None] < idx[:, None, None])), axis=1)
    lo = jnp.sum(jnp.where(rank == f_eff, g, 0.0), axis=0)
    hi = jnp.sum(jnp.where(rank == n - 1 - f_eff, g, 0.0), axis=0)
    return lo, hi


def dense_diagnostics(grads: jnp.ndarray, gradient: jnp.ndarray,
                      selected: jnp.ndarray, scores: jnp.ndarray,
                      f: int, step: jnp.ndarray,
                      reputation: jnp.ndarray,
                      staleness: jnp.ndarray) -> AggDiagnostics:
    """Assemble one forensics row on the dense ``(n, d)`` path.

    Pure fp32 reductions over a :data:`OBS_SKETCH`-bounded coordinate
    sketch of the stack the rule consumed and the result it emitted —
    nothing feeds back into the data path.  Norm-like fields
    (``dist_to_agg``, ``agg_dev``, ``spread``) are scaled by
    ``sqrt(d / S)`` to estimate their full-space magnitude.

    Args:
      grads: the ``(n, *dims)`` worker stack the rule saw.
      gradient: the emitted aggregate, shape ``dims``.
      selected: ``(n,)`` selection mask/weights from the result.
      scores: ``(n,)`` per-worker rule scores from the result.
      f: declared Byzantine bound (static; clamped to ``(n-1)//2`` for
        the trimmed-range bound).
      step: aggregation step counter to stamp on the record.
      reputation: ``(n,)`` fp32 post-call reputation snapshot.
      staleness: ``(n,)`` fp32 staleness snapshot.

    Returns:
      A fully-populated :class:`AggDiagnostics`.
    """
    n = grads.shape[0]
    d = int(grads[0].size)
    g = _sketch_dense(grads.reshape(n, -1)).astype(jnp.float32)
    a = _sketch_dense(gradient.reshape(1, -1)).astype(jnp.float32)[0]
    scale = float(d / g.shape[1]) ** 0.5
    dist = jnp.sqrt(jnp.sum((g - a[None]) ** 2, axis=1)) * scale
    lo, hi = _trim_bounds(g, f)
    out_mask = (g < lo[None]) | (g > hi[None])
    trimmed = jnp.mean(out_mask.astype(jnp.float32), axis=1)
    agg_dev = jnp.linalg.norm(a - jnp.mean(g, axis=0)) * scale
    return AggDiagnostics(
        step=step.astype(jnp.float32),
        selected=selected.astype(jnp.float32),
        scores=scores.astype(jnp.float32),
        dist_to_agg=dist, trimmed_frac=trimmed,
        reputation=reputation, staleness=staleness,
        agg_dev=agg_dev, spread=jnp.mean(dist))


def tree_diagnostics(leaves: Sequence[jnp.ndarray],
                     agg_leaves: Sequence[jnp.ndarray],
                     selected: jnp.ndarray, scores: jnp.ndarray,
                     f: int, step: jnp.ndarray,
                     reputation: jnp.ndarray,
                     staleness: jnp.ndarray) -> AggDiagnostics:
    """Assemble one forensics row on the sharded tree path.

    The :data:`OBS_SKETCH` coordinate budget is apportioned over the
    leaves by size — each leaf contributes one centered contiguous
    slice of its flattened coordinates, so no flat ``(n, d)`` matrix is
    ever materialized (the sharded engine's invariant) and no leaf's
    full memory is re-read.  Norm-like fields are scaled by
    ``sqrt(d / S)`` back to full-space magnitude.

    Args:
      leaves: worker-stacked ``(n, *dims)`` leaves the rule saw.
      agg_leaves: the emitted aggregate's leaves, shapes ``dims``.
      selected: ``(n,)`` selection mask/weights from the result.
      scores: ``(n,)`` per-worker rule scores from the result.
      f: declared Byzantine bound (static).
      step: aggregation step counter to stamp on the record.
      reputation: ``(n,)`` fp32 post-call reputation snapshot.
      staleness: ``(n,)`` fp32 staleness snapshot.

    Returns:
      A fully-populated :class:`AggDiagnostics`.
    """
    n = leaves[0].shape[0]
    total = sum(int(leaf[0].size) for leaf in leaves)
    d2 = jnp.zeros((n,), jnp.float32)
    dev2 = jnp.zeros((), jnp.float32)
    out_count = jnp.zeros((n,), jnp.float32)
    coords = 0
    for leaf, agg in zip(leaves, agg_leaves):
        d_leaf = int(leaf[0].size)
        s_leaf = max(1, min(d_leaf, round(OBS_SKETCH * d_leaf / total)))
        start = (d_leaf - s_leaf) // 2
        g = leaf.reshape(n, -1)[:, start:start + s_leaf]
        g = g.astype(jnp.float32)
        a = jnp.asarray(agg, jnp.float32).reshape(-1)[start:start + s_leaf]
        d2 = d2 + jnp.sum((g - a[None]) ** 2, axis=1)
        dev2 = dev2 + jnp.sum((a - jnp.mean(g, axis=0)) ** 2)
        lo, hi = _trim_bounds(g, f)
        out_mask = (g < lo[None]) | (g > hi[None])
        out_count = out_count + jnp.sum(out_mask.astype(jnp.float32),
                                        axis=1)
        coords += s_leaf
    scale = float(total / max(coords, 1)) ** 0.5
    dist = jnp.sqrt(d2) * scale
    return AggDiagnostics(
        step=step.astype(jnp.float32),
        selected=selected.astype(jnp.float32),
        scores=scores.astype(jnp.float32),
        dist_to_agg=dist,
        trimmed_frac=out_count / max(coords, 1),
        reputation=reputation, staleness=staleness,
        agg_dev=jnp.sqrt(dev2) * scale, spread=jnp.mean(dist))


def make_obs(name: str, base: AggregatorRule,
             capacity: Optional[int] = None) -> AggregatorRule:
    """Build the ``obs-<base>`` telemetry composite around any rule.

    The composite is stateful with ``"obs"`` prepended to the base's
    ``state_fields``; ``repro.agg.state.init_state`` allocates the
    :class:`~repro.obs.buffer.MetricsBuffer` ring from the rule's
    ``obs_capacity``.  The base runs on the untouched stack and its
    result is passed through **bitwise-unchanged** — only the carried
    ring differs from the uninstrumented rule.  Quorum, resilience and
    invariants are inherited verbatim.

    Args:
      name: composite registry name (``"obs-<base>"``).
      base: the resolved base rule; its tree implementation is wrapped
        only when it has one.
      capacity: ring rows to allocate (``None`` =
        :data:`~repro.obs.buffer.DEFAULT_OBS_CAPACITY`).

    Returns:
      A stateful :class:`AggregatorRule` recording one diagnostics row
      per application into ``AggState.obs``.
    """
    state_fields: Tuple[str, ...] = ("obs",) + tuple(
        fld for fld in base.state_fields if fld != "obs")

    def dense(grads, f, state):
        if base.stateful:
            res, state = base.dense_fn(grads, f, state)
        else:
            res = base.dense_fn(grads, f)
            state = state._replace(step=state.step + 1)
        rep, stale = _worker_snapshots(state, base, grads.shape[0])
        rec = dense_diagnostics(grads, res.gradient, res.selected,
                                res.scores, f, state.step, rep, stale)
        return res, state._replace(obs=push_record(state.obs, rec))

    tree_fn = None
    if base.tree_fn is not None:
        def tree_fn(ctx: TreeContext, state):
            if base.stateful:
                out, state = base.tree_fn(ctx, state)
            else:
                out = base.tree_fn(ctx)
                state = state._replace(step=state.step + 1)
            rep, stale = _worker_snapshots(state, base, ctx.n)
            rec = tree_diagnostics(ctx.leaves, out.leaves, out.selected,
                                   out.scores, ctx.f, state.step, rep,
                                   stale)
            return out, state._replace(obs=push_record(state.obs, rec))

    return AggregatorRule(
        name=name, min_n=base.min_n, dense_fn=dense, tree_fn=tree_fn,
        byzantine_resilient=base.byzantine_resilient, stateful=True,
        state_fields=state_fields, history_window=base.history_window,
        invariants=base.invariants,
        obs_capacity=capacity or DEFAULT_OBS_CAPACITY,
        doc=f"forensics-recording wrapper around {base.name} "
            f"(bitwise data path)")
