"""Jit-compatible metrics ring for aggregation forensics.

The telemetry layer (``obs-<base>`` rules, see ``repro.obs.forensics``)
records one :class:`AggDiagnostics` row per aggregation call into a
fixed-size :class:`MetricsBuffer` ring that is carried through compiled
steps exactly like ``AggState`` — it is a pytree of arrays, so it rides
``jax.jit``, ``lax.scan`` carries, ``jax.eval_shape`` and checkpoint
flatten/unflatten with no host callbacks.  The host drains it between
steps (or at the end of a run) with :func:`drain`.

Every field is fp32 (or int32 for the cursor) so the ring obeys the
repo-wide fp32 aggregation contract and never perturbs the wrapped
rule's numerics — the wrapper only *reads* the rule's outputs.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AggDiagnostics",
    "DEFAULT_OBS_CAPACITY",
    "MetricsBuffer",
    "drain",
    "init_metrics_buffer",
    "push_record",
]

DEFAULT_OBS_CAPACITY = 64
"""Ring rows allocated when an ``obs-<base>`` rule does not override
``AggregatorRule.obs_capacity``.  64 rows x a handful of (n,) fp32
vectors is a few KiB — negligible against the gradient stack."""


class AggDiagnostics(NamedTuple):
    """One structured forensics row emitted per aggregation call.

    All per-worker vectors are length ``n`` (the worker axis of the
    stack that was aggregated) and fp32; scalars are fp32 ``()``.

    Fields:
      step: aggregation step counter at record time.
      selected: per-worker selection mask/weight as reported by the
        wrapped rule (``res.selected``), normalised to fp32.
      scores: per-worker rule scores (Krum scores, trimmed-mean
        weights, ...; ``res.scores``).
      dist_to_agg: per-worker L2 distance from each submitted gradient
        to the emitted aggregate — the suspicion primitive.
      trimmed_frac: fraction of coordinates where the worker falls
        outside the per-coordinate ``f``-trimmed range (coordinate-wise
        outlier mass).
      reputation: per-worker reputation snapshot after the call (ones
        when the wrapped rule carries no reputation state).
      staleness: per-worker staleness ``step - version`` read from the
        gradient bus (zeros on synchronous paths).
      agg_dev: L2 distance between the aggregate and the plain mean of
        the stack — the empirical poisoning-leeway proxy's numerator.
      spread: mean of ``dist_to_agg`` — the proxy's denominator.
    """

    step: jnp.ndarray
    selected: jnp.ndarray
    scores: jnp.ndarray
    dist_to_agg: jnp.ndarray
    trimmed_frac: jnp.ndarray
    reputation: jnp.ndarray
    staleness: jnp.ndarray
    agg_dev: jnp.ndarray
    spread: jnp.ndarray


class MetricsBuffer(NamedTuple):
    """Fixed-size in-graph ring of :class:`AggDiagnostics` rows.

    Fields:
      cursor: int32 ``()`` — total records pushed since init (not
        wrapped; ``cursor % capacity`` is the next write slot, so the
        host can tell how many rows are valid and whether any were
        overwritten).
      records: an :class:`AggDiagnostics` whose every leaf carries a
        leading ``(capacity,)`` axis — the ring storage.
      sel_total: fp32 ``(n,)`` — cumulative per-worker selection weight
        over *all* pushes, not just the ones still in the ring, so
        selection frequency survives ring wraparound.
    """

    cursor: jnp.ndarray
    records: AggDiagnostics
    sel_total: jnp.ndarray

    @property
    def capacity(self) -> int:
        """Ring size (static — the leading axis of every record leaf)."""
        return int(self.records.step.shape[0])


def init_metrics_buffer(capacity: int, n: int) -> MetricsBuffer:
    """Allocate an empty ring for ``n``-worker diagnostics.

    Args:
      capacity: number of ring rows (static; see
        ``DEFAULT_OBS_CAPACITY``).
      n: worker-axis length of the stacks this buffer will observe.

    Returns:
      A zero-initialised :class:`MetricsBuffer` with ``cursor == 0``.
    """
    vec = jnp.zeros((capacity, n), jnp.float32)
    scalar = jnp.zeros((capacity,), jnp.float32)
    records = AggDiagnostics(
        step=scalar, selected=vec, scores=vec, dist_to_agg=vec,
        trimmed_frac=vec, reputation=vec, staleness=vec,
        agg_dev=scalar, spread=scalar)
    return MetricsBuffer(cursor=jnp.zeros((), jnp.int32), records=records,
                         sel_total=jnp.zeros((n,), jnp.float32))


def push_record(buf: MetricsBuffer, rec: AggDiagnostics) -> MetricsBuffer:
    """Append one diagnostics row, overwriting the oldest on overflow.

    Pure and jit-safe: the write lands at ``cursor % capacity`` via
    ``.at[idx].set`` and the cursor advances by one.

    Args:
      buf: ring to append to.
      rec: row to write; every leaf must match the per-row shape of
        ``buf.records`` (fp32 ``(n,)`` vectors / ``()`` scalars).

    Returns:
      The updated :class:`MetricsBuffer`.
    """
    cap = buf.capacity
    idx = buf.cursor % cap
    records = jax.tree_util.tree_map(
        lambda store, row: store.at[idx].set(row.astype(store.dtype)),
        buf.records, rec)
    return MetricsBuffer(cursor=buf.cursor + 1, records=records,
                         sel_total=buf.sel_total
                         + rec.selected.astype(jnp.float32))


def drain(buf: Any) -> Dict[str, Any]:
    """Read a :class:`MetricsBuffer` out to host numpy, oldest-first.

    Host-side only — call it between steps on a concrete buffer, never
    inside a compiled function.

    Args:
      buf: a :class:`MetricsBuffer` (device or host), or the empty
        pytree ``()`` that an un-instrumented ``AggState.obs`` carries.

    Returns:
      A dict with ``"pushed"`` (total rows ever written), ``"records"``
      (list of per-row dicts in chronological order, at most
      ``capacity`` long), and ``"selection_frequency"`` (``(n,)`` numpy
      array of per-worker selection shares over the whole run; empty
      array when nothing was recorded).  For ``buf=()`` all fields are
      empty/zero.
    """
    if buf is None or (isinstance(buf, tuple) and not
                       isinstance(buf, MetricsBuffer) and len(buf) == 0):
        return {"pushed": 0, "records": [],
                "selection_frequency": np.zeros((0,), np.float32)}
    cursor = int(np.asarray(buf.cursor))
    cap = int(np.asarray(buf.records.step).shape[0])
    valid = min(cursor, cap)
    records = jax.tree_util.tree_map(np.asarray, buf.records)
    # chronological order: on wraparound the oldest row sits at
    # cursor % cap, otherwise rows 0..valid-1 are already ordered
    if cursor > cap:
        order = (np.arange(cap) + cursor % cap) % cap
    else:
        order = np.arange(valid)
    rows = []
    for i in order[:valid]:
        rows.append({f: np.asarray(getattr(records, f)[i])
                     for f in AggDiagnostics._fields})
    sel_total = np.asarray(buf.sel_total, np.float32)
    total = float(sel_total.sum())
    freq = sel_total / total if total > 0 else np.zeros_like(sel_total)
    return {"pushed": cursor, "records": rows,
            "selection_frequency": freq}
