"""ShapeDtypeStruct input specs + shardings for every (arch x input shape).

Nothing here allocates device memory: parameters, optimizer state, and
caches come from ``jax.eval_shape``; inputs are hand-built structs.  The
dry-run lowers against these, exactly like shannon/kernels-style dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_reduced
from repro.dist.mesh import mesh_axis_sizes
from repro.dist.sharding import (batch_pspec, cache_shardings,
                                 ensemble_cache_shardings,
                                 ensemble_param_shardings, param_shardings)
from repro.models import init_cache, init_model
from repro.models.config import INPUT_SHAPES, ModelConfig
from repro.models.layers import _dtype


def sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_specs(cfg: ModelConfig, mesh) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct pytree, NamedSharding pytree) for the params."""
    shapes = jax.eval_shape(
        lambda k: init_model(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    shardings = param_shardings(shapes, mesh)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return structs, shardings


def opt_specs(param_structs, optimizer, mesh) -> Tuple[Any, Any]:
    shapes = jax.eval_shape(optimizer.init, param_structs)
    shardings = param_shardings(shapes, mesh)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return structs, shardings


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int, mesh
                ) -> Tuple[Any, Any]:
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
    shardings = cache_shardings(shapes, mesh)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return structs, shardings


def _stack_structs(shapes: Any, n_replicas: int) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n_replicas,) + tuple(s.shape),
                                       s.dtype), shapes)


def ensemble_param_specs(cfg: ModelConfig, mesh, n_replicas: int
                         ) -> Tuple[Any, Any]:
    """(structs, shardings) for a replica-stacked parameter ensemble.

    Same eval_shape derivation as ``param_specs`` with every leaf grown a
    leading ``(n_replicas,)`` axis, sharded by
    ``ensemble_param_shardings`` (replica axis over ``data``, inner dims
    over ``model``) — the layout ``repro.dist.serve_robust`` consumes.
    """
    shapes = jax.eval_shape(
        lambda k: init_model(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    stacked = _stack_structs(shapes, n_replicas)
    shardings = ensemble_param_shardings(stacked, mesh)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        stacked, shardings)
    return structs, shardings


def ensemble_cache_specs(cfg: ModelConfig, n_replicas: int, batch: int,
                         cache_len: int, mesh) -> Tuple[Any, Any]:
    """(structs, shardings) for replica-stacked decode caches."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
    stacked = _stack_structs(shapes, n_replicas)
    shardings = ensemble_cache_shardings(stacked, mesh)
    structs = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        stacked, shardings)
    return structs, shardings


def input_specs(cfg: ModelConfig, shape_name: str, mesh) -> Dict[str, Any]:
    """Model-input structs for one assigned input shape.

    train:    {"tokens","labels"[,"extra"]}  (n_workers, per_worker, S)
    prefill:  {"tokens"[,"extra"]}           (B, S)
    decode:   {"token","pos"}                (B, 1), scalar
    """
    shp = INPUT_SHAPES[shape_name]
    sizes = mesh_axis_sizes(mesh)
    dt = _dtype(cfg.param_dtype)
    enc = cfg.encoder_seq or cfg.vision_seq

    if shp.kind == "train":
        n_workers = sizes["data"]
        pw = shp.global_batch // n_workers
        tspec = batch_pspec((n_workers, pw, shp.seq_len), mesh,
                            worker_axis=True)
        out = {
            "tokens": sds((n_workers, pw, shp.seq_len), jnp.int32, mesh,
                          tspec),
            "labels": sds((n_workers, pw, shp.seq_len), jnp.int32, mesh,
                          tspec),
        }
        if cfg.arch_type in ("audio", "vlm"):
            espec = batch_pspec((n_workers, pw, enc, cfg.d_model), mesh,
                                worker_axis=True)
            out["extra"] = sds((n_workers, pw, enc, cfg.d_model), dt, mesh,
                               espec)
        return out

    if shp.kind == "prefill":
        b = shp.global_batch
        tspec = batch_pspec((b, shp.seq_len), mesh, worker_axis=False)
        out = {"tokens": sds((b, shp.seq_len), jnp.int32, mesh, tspec)}
        if cfg.arch_type in ("audio", "vlm"):
            espec = batch_pspec((b, enc, cfg.d_model), mesh,
                                worker_axis=False)
            out["extra"] = sds((b, enc, cfg.d_model), dt, mesh, espec)
        return out

    # decode
    b = shp.global_batch
    tspec = batch_pspec((b, 1), mesh, worker_axis=False)
    return {
        "token": sds((b, 1), jnp.int32, mesh, tspec),
        "pos": sds((), jnp.int32, mesh, P()),
    }
