import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production meshes, and extract the roofline terms from the compiled
artifact.

MUST be run as its own process (`python -m repro.launch.dryrun ...`): the
XLA_FLAGS override above executes before any other import so that jax
initializes with 512 host placeholder devices.  Smoke tests and benchmarks
never import this module.

Outputs a JSON artifact per run with:
  memory_analysis   bytes per device (argument/output/temp/generated code)
  cost_analysis     HLO flops / bytes accessed
  collectives       per-op-kind byte totals parsed from the compiled HLO
  roofline          the three terms (compute/memory/collective, seconds)
                    against v5e constants, the dominant term, and the
                    MODEL_FLOPS / HLO_FLOPS utilization ratio
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional

# v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, top_k: int = 12):
    """Sum result-shape bytes of every collective op in the HLO.

    Convention: we count the *result* shape of each op (= operand shape for
    all-reduce / collective-permute; the gathered size for all-gather; the
    scattered size for reduce-scatter).  Counts are per-instruction in the
    SPMD module, i.e. per-device traffic.  Also returns the ``top_k``
    largest individual collective ops (kind, bytes, result type) for the
    perf-iteration loop."""
    out: Dict[str, Dict[str, float]] = {
        k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    ops = []
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            # match "= TYPE kind(" including tuple types, and -start forms
            m = re.search(r"=\s+(.*?)\s+" + kind + r"(-start)?\(", line)
            if m:
                b = _shape_bytes(m.group(1))
                out[kind]["bytes"] += b
                out[kind]["count"] += 1
                ops.append((kind, b, m.group(1)[:120]))
                break
    ops.sort(key=lambda t: -t[1])
    return out, [{"kind": k, "bytes": b, "type": t}
                 for k, b, t in ops[:top_k]]


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def _memory_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {"unavailable": True}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D for training, 2 N_active D for inference."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            gar: str = "bulyan-krum", attack: str = "none",
            reduced: bool = False, impl: str = "auto",
            optimizer_name: str = "momentum", moe_impl: Optional[str] = None,
            param_dtype: Optional[str] = None, agg_dtype: str = "native",
            distance_backend: str = "auto", unroll: bool = False,
            rep_lr: Optional[float] = None,
            async_tau: Optional[int] = None, async_schedule: str = "fixed",
            attn_shard: Optional[str] = None,
            logits_dtype: Optional[str] = None,
            serve_gar: Optional[str] = None, serve_f: int = 2,
            serve_replicas: int = 0, serve_speculative_k: int = 0,
            telemetry: bool = False,
            out_path: Optional[str] = None) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp

    from repro.agg import quorum
    from repro.configs import get_config, get_reduced, shape_applicable
    from repro.dist.async_train import (init_async_state,
                                        make_async_train_step)
    from repro.dist.mesh import make_production_mesh
    from repro.dist.serve import make_prefill_step, make_serve_step
    from repro.dist.serve_robust import (init_ensemble_state,
                                         make_robust_serve_step,
                                         make_robust_verify_step)
    from repro.dist.train import (DistByzantineSpec, init_agg_state,
                                  make_train_step)
    from repro.launch import specs as S
    from repro.models.config import INPUT_SHAPES
    from repro.optim import get_optimizer

    assert jax.device_count() == 512, (
        "dryrun must own the process (512 host devices); run via "
        "python -m repro.launch.dryrun")

    if not shape_applicable(arch, shape_name):
        rec = {"arch": arch, "shape": shape_name, "skipped": True,
               "reason": "long_500k not applicable (see DESIGN.md §6)"}
        if out_path:
            with open(out_path, "w") as fh:
                json.dump(rec, fh, indent=1)
        return rec

    import dataclasses

    cfg = get_reduced(arch) if reduced else get_config(arch)
    overrides = {}
    if moe_impl:
        overrides["moe_impl"] = moe_impl
    if param_dtype:
        overrides["param_dtype"] = param_dtype
    if unroll:
        overrides["unroll_scan"] = True
    if attn_shard:
        overrides["attn_shard"] = attn_shard
    if logits_dtype:
        overrides["logits_dtype"] = logits_dtype
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "gar": gar, "attack": attack,
        "reduced": reduced, "impl": impl, "overrides": overrides,
        "agg_dtype": agg_dtype, "distance_backend": distance_backend,
        "telemetry": telemetry,
    }
    n_chips = mesh.devices.size
    t0 = time.time()

    with mesh:
        params, param_sh = S.param_specs(cfg, mesh)
        inputs = S.input_specs(cfg, shape_name, mesh)

        if shape.kind == "train" and async_tau is not None:
            # asynchronous bounded-staleness train step: the GradientBus
            # (per-worker versioned slots) rides in the carried AggState,
            # initialized abstractly so nothing is materialized
            opt = get_optimizer(optimizer_name, 1e-3)
            opt_state, opt_sh = S.opt_specs(params, opt, mesh)
            spec = DistByzantineSpec(f=3, gar=gar, attack=attack,
                                     agg_dtype=agg_dtype,
                                     distance_backend=distance_backend,
                                     rep_lr=rep_lr,
                                     async_tau=async_tau,
                                     async_schedule=async_schedule,
                                     telemetry=telemetry)
            record.update(async_tau=async_tau,
                          async_schedule=async_schedule)
            if rep_lr is not None:
                record.update(rep_lr=rep_lr)
            step = make_async_train_step(cfg, spec, opt, impl=impl,
                                         mesh=mesh)
            n_workers = inputs["tokens"].shape[0]
            agg_state = jax.eval_shape(
                lambda: init_async_state(spec, params, n_workers))
            jitted = jax.jit(step, donate_argnums=(0, 1),
                             out_shardings=(param_sh, opt_sh, None, None))
            lowered = jitted.lower(params, opt_state, inputs, agg_state)
        elif shape.kind == "train":
            opt = get_optimizer(optimizer_name, 1e-3)
            opt_state, opt_sh = S.opt_specs(params, opt, mesh)
            spec = DistByzantineSpec(f=3, gar=gar, attack=attack,
                                     agg_dtype=agg_dtype,
                                     distance_backend=distance_backend,
                                     rep_lr=rep_lr,
                                     telemetry=telemetry)
            if rep_lr is not None:
                record.update(rep_lr=rep_lr)
            step = make_train_step(cfg, spec, opt, impl=impl, mesh=mesh)
            if spec.rule().stateful:
                # abstract AggState: eval_shape keeps the (W, n, ...)
                # history buffers as structs — nothing is materialized
                n_workers = inputs["tokens"].shape[0]
                agg_state = jax.eval_shape(
                    lambda: init_agg_state(spec, params, n_workers))
                jitted = jax.jit(step, donate_argnums=(0, 1),
                                 out_shardings=(param_sh, opt_sh, None,
                                                None))
                lowered = jitted.lower(params, opt_state, inputs, agg_state)
            else:
                jitted = jax.jit(step, donate_argnums=(0, 1),
                                 out_shardings=(param_sh, opt_sh, None))
                lowered = jitted.lower(params, opt_state, inputs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, impl=impl)
            jitted = jax.jit(step)
            args = [params, inputs["tokens"]]
            if "extra" in inputs:
                args.append(inputs["extra"])
            lowered = jitted.lower(*args)
        elif shape.kind == "decode" and serve_gar:
            # robust ensemble decode: replica-stacked params/caches with
            # the replica axis on ``data``, per-token logits aggregation
            # through the registry (repro.dist.serve_robust)
            n_rep = serve_replicas or quorum(serve_gar, serve_f)
            sspec = DistByzantineSpec(f=serve_f, gar=serve_gar,
                                      agg_dtype=agg_dtype,
                                      distance_backend=distance_backend,
                                      speculative_k=serve_speculative_k,
                                      telemetry=telemetry)
            record.update(serve_gar=serve_gar, serve_f=serve_f,
                          serve_replicas=n_rep,
                          serve_speculative_k=serve_speculative_k)
            eparams, _ = S.ensemble_param_specs(cfg, mesh, n_rep)
            cache, cache_sh = S.ensemble_cache_specs(
                cfg, n_rep, shape.global_batch, shape.seq_len, mesh)
            agg_state = None
            if sspec.rule().stateful:
                agg_state = jax.eval_shape(
                    lambda: init_ensemble_state(sspec, n_rep,
                                                shape.global_batch,
                                                cfg.vocab_size))
            if serve_speculative_k >= 1:
                # speculative verify: the whole (B, k) draft block through
                # one batched robust-aggregation step, per-slot positions
                from jax.sharding import PartitionSpec as P
                b = shape.global_batch
                block = S.sds((b, serve_speculative_k), jnp.int32, mesh,
                              inputs["token"].sharding.spec)
                posv = S.sds((b,), jnp.int32, mesh, P())
                step = make_robust_verify_step(cfg, sspec, mesh=mesh)
                jitted = jax.jit(step, donate_argnums=(1,),
                                 out_shardings=(None, cache_sh, None, None))
                lowered = jitted.lower(eparams, cache, block, posv,
                                       agg_state)
            else:
                step = make_robust_serve_step(cfg, sspec, mesh=mesh)
                jitted = jax.jit(step, donate_argnums=(1,),
                                 out_shardings=(None, cache_sh, None, None))
                lowered = jitted.lower(eparams, cache, inputs["token"],
                                       inputs["pos"], agg_state)
        else:  # decode
            cache, cache_sh = S.cache_specs(cfg, shape.global_batch,
                                            shape.seq_len, mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(step, donate_argnums=(1,),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params, cache, inputs["token"],
                                   inputs["pos"])

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

    mem = _memory_dict(compiled)
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll, top_ops = parse_collectives(hlo)
    record["memory_analysis"] = mem
    record["cost_analysis"] = {k: cost[k] for k in
                               ("flops", "bytes accessed")
                               if k in cost} or cost
    record["collectives"] = coll
    record["top_collective_ops"] = top_ops
    record["hlo_lines"] = hlo.count("\n")

    # roofline terms.  cost_analysis on the SPMD module is per-device.
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes accessed", 0.0)
    coll_bytes = sum(v["bytes"] for v in coll.values())
    mf = model_flops(cfg, shape)
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_acc / HBM_BW
    coll_t = coll_bytes / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    record["roofline"] = {
        **terms,
        "dominant": max(terms, key=terms.get),
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_chips,
        "hlo_flops_per_chip": flops,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
        "collective_bytes_per_chip": coll_bytes,
    }

    if out_path:
        with open(out_path, "w") as fh:
            json.dump(record, fh, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True,
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gar", default="bulyan-krum")
    ap.add_argument("--attack", default="none")
    ap.add_argument("--impl", default="auto",
                    help="attention impl: auto|naive|blockwise")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (fast sanity check)")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "einsum", "scatter"],
                    help="override cfg.moe_impl (perf iterations)")
    ap.add_argument("--param-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--agg-dtype", default="native",
                    choices=["native", "bfloat16", "float32"],
                    help="gradient dtype for the robust aggregation")
    ap.add_argument("--distance-backend", default="auto",
                    choices=["auto", "xla", "pallas", "fused"],
                    help="pairwise-distance implementation for distance-"
                         "based GARs (pallas = shard-mapped tiled kernel; "
                         "fused = single-sweep megakernel, rules lowered "
                         "onto their fused-<base> composites; "
                         "auto = pallas on TPU, xla elsewhere)")
    ap.add_argument("--rep-lr", type=float, default=None,
                    help="reputation EMA rate for --gar reputation-<base> "
                         "(truthy values also switch on the reputation-"
                         "scaled step size; see repro.agg.reputation)")
    ap.add_argument("--async-tau", type=int, default=None,
                    help="lower the asynchronous bounded-staleness train "
                         "step instead of the synchronous one (train "
                         "shapes only): per-worker staleness bound of "
                         "the GradientBus delay schedule; pair with "
                         "--gar stale-<base> for staleness-weighted "
                         "aggregation (repro.dist.async_train)")
    ap.add_argument("--async-schedule", default="fixed",
                    choices=["fixed", "random"],
                    help="deterministic delay schedule of --async-tau "
                         "(fixed = staggered round-robin, random = "
                         "bounded Bernoulli)")
    ap.add_argument("--expert-gather", action="store_true",
                    help="constrain expert weights to TP-only at use site "
                         "(per-layer all-gather instead of activation "
                         "all-reduce; see repro.models.moe)")
    ap.add_argument("--legacy-sharding", action="store_true",
                    help="pre-iteration param sharding rules (A/B baseline)")
    ap.add_argument("--logits-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--serve-gar", default=None,
                    help="robust ensemble decode: aggregate per-token "
                         "replica logits with this GAR (decode shapes "
                         "only; see repro.dist.serve_robust)")
    ap.add_argument("--serve-f", type=int, default=2,
                    help="Byzantine replica bound of --serve-gar")
    ap.add_argument("--serve-speculative-k", type=int, default=0,
                    help="lower the robust speculative verify step for "
                         "(B, k) draft blocks instead of the per-token "
                         "serve step (decode shapes with --serve-gar)")
    ap.add_argument("--serve-replicas", type=int, default=0,
                    help="ensemble size (0 = the rule's minimal quorum "
                         "for --serve-f)")
    ap.add_argument("--attn-shard", default=None,
                    choices=[None, "none", "batch"],
                    help="attention activation sharding (see ModelConfig)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan: analysis-grade costs "
                         "(cost_analysis/HLO parsing see while bodies "
                         "once; rolled runs undercount per-step work)")
    ap.add_argument("--telemetry", action="store_true",
                    help="compile with aggregation forensics on (wraps "
                         "the GAR in its obs-* composite; the carried "
                         "AggState gains a fixed-size metrics ring)")
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args()
    if args.legacy_sharding:
        from repro.dist import sharding as _sh
        _sh.LEGACY_RULES = True
    if args.expert_gather:
        from repro.models import moe as _moe
        _moe.EXPERT_WEIGHT_GATHER = True
    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  gar=args.gar, attack=args.attack, reduced=args.reduced,
                  impl=args.impl, moe_impl=args.moe_impl,
                  param_dtype=args.param_dtype, agg_dtype=args.agg_dtype,
                  distance_backend=args.distance_backend,
                  rep_lr=args.rep_lr,
                  async_tau=args.async_tau,
                  async_schedule=args.async_schedule,
                  unroll=args.unroll, attn_shard=args.attn_shard,
                  logits_dtype=args.logits_dtype,
                  serve_gar=args.serve_gar, serve_f=args.serve_f,
                  serve_replicas=args.serve_replicas,
                  serve_speculative_k=args.serve_speculative_k,
                  telemetry=args.telemetry,
                  out_path=args.out)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
