"""Render the dry-run artifact directory into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.summarize [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(art_dir: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as fh:
            recs.append(json.load(fh))
    return recs


def _gb(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compile s | HLO flops/chip | bytes/chip"
            " | temp GiB/chip | AG GiB | AR GiB | PERM GiB | A2A GiB |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped") or "error" in r:
            continue
        c = r["collectives"]
        mem = r.get("memory_analysis", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('compile_s', '?')} "
            f"| {r['cost_analysis'].get('flops', 0):.3g} "
            f"| {r['cost_analysis'].get('bytes accessed', 0):.3g} "
            f"| {_gb(mem.get('temp_size_in_bytes', 0))} "
            f"| {_gb(c['all-gather']['bytes'])} "
            f"| {_gb(c['all-reduce']['bytes'])} "
            f"| {_gb(c['collective-permute']['bytes'])} "
            f"| {_gb(c['all-to-all']['bytes'])} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], pod: str = "16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful flops ratio | note |",
            "|---|---|---|---|---|---|---|---|"]
    seen_skips = set()
    for r in recs:
        if r.get("skipped"):
            key = (r["arch"], r["shape"])
            if key in seen_skips:
                continue
            seen_skips.add(key)
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| skipped: sub-quadratic n/a |")
            continue
        if "error" in r or r.get("mesh") != pod:
            continue
        ro = r["roofline"]
        ur = ro.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {ro['compute_s']:.4f} | {ro['memory_s']:.4f} "
            f"| {ro['collective_s']:.4f} "
            f"| **{ro['dominant'].replace('_s', '')}** "
            f"| {ur:.3f} | |" if ur else
            f"| {r['arch']} | {r['shape']} "
            f"| {ro['compute_s']:.4f} | {ro['memory_s']:.4f} "
            f"| {ro['collective_s']:.4f} "
            f"| **{ro['dominant'].replace('_s', '')}** | n/a | |")
    return "\n".join(rows)


def interesting(recs: List[Dict]) -> str:
    """Rank candidates for the perf hillclimb."""
    out = []
    for r in recs:
        if r.get("skipped") or "error" in r or r.get("mesh") != "16x16":
            continue
        ro = r["roofline"]
        tot = ro["compute_s"] + ro["memory_s"] + ro["collective_s"]
        out.append((r["arch"], r["shape"], ro["dominant"],
                    ro["compute_s"] / max(tot, 1e-12),
                    ro.get("useful_flops_ratio") or 0.0, tot))
    out.sort(key=lambda t: t[3])  # worst compute fraction first
    lines = ["arch shape dominant compute_frac useful_ratio total_s"]
    for t in out:
        lines.append(f"{t[0]:24s} {t[1]:12s} {t[2]:13s} {t[3]:.3f} "
                     f"{t[4]:.3f} {t[5]:.3f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mode", default="all",
                    choices=["all", "dryrun", "roofline", "interesting"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.mode in ("all", "dryrun"):
        print("## Dry-run table\n")
        print(dryrun_table(recs))
    if args.mode in ("all", "roofline"):
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_table(recs))
    if args.mode in ("all", "interesting"):
        print("\n## Hillclimb candidates (sorted by compute fraction)\n")
        print(interesting(recs))


if __name__ == "__main__":
    main()
