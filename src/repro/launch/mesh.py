"""Production mesh entry point (assignment-specified location).

``make_production_mesh`` is a function — importing this module never
touches jax device state.
"""
from repro.dist.mesh import make_host_mesh, make_production_mesh, mesh_axis_sizes

__all__ = ["make_host_mesh", "make_production_mesh", "mesh_axis_sizes"]
