"""Run the full dry-run matrix: every (arch x shape x mesh) combo as a
subprocess (each needs its own 512-device jax init), one JSON artifact
each.  Resumable: existing artifacts are skipped.

    PYTHONPATH=src python -m repro.launch.sweep --out artifacts/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    # smallest first so failures surface early
    "mamba2-130m", "gemma3-1b", "gemma-2b", "whisper-medium",
    "llama3.2-3b", "qwen1.5-4b", "llama-3.2-vision-11b",
    "llama4-scout-17b-a16e", "mixtral-8x22b", "jamba-1.5-large-398b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--gar", default="bulyan-krum")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--pods", default="both", choices=["1", "2", "both"])
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    pods = {"1": [False], "2": [True], "both": [False, True]}[args.pods]
    todo = [(a, s, mp) for mp in pods for a in ARCHS for s in SHAPES
            if args.only_arch in (None, a)]
    t_start = time.time()
    for i, (arch, shape, mp) in enumerate(todo):
        tag = f"{arch}.{shape}.pod{'2' if mp else '1'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[{i+1}/{len(todo)}] {tag}: exists, skip", flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--gar", args.gar,
               "--impl", args.impl, "--out", path]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode != 0:
                with open(path, "w") as fh:
                    json.dump({"arch": arch, "shape": shape,
                               "multi_pod": mp, "error":
                               r.stderr[-4000:]}, fh, indent=1)
                status = "FAIL"
            else:
                rec = json.load(open(path))
                status = ("skip(n/a)" if rec.get("skipped")
                          else rec["roofline"]["dominant"]
                          if "roofline" in rec else "ok")
        except subprocess.TimeoutExpired:
            with open(path, "w") as fh:
                json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": f"timeout {args.timeout}s"}, fh,
                          indent=1)
            status = "TIMEOUT"
        dt = time.time() - t0
        total = time.time() - t_start
        print(f"[{i+1}/{len(todo)}] {tag}: {status} ({dt:.0f}s, "
              f"total {total/60:.1f}m)", flush=True)


if __name__ == "__main__":
    main()
