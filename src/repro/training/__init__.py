from repro.training.trainer import (AsyncByzantineTrainer, ByzantineSpec,
                                    ByzantineTrainer, init_flat_agg_state,
                                    init_flat_async_state,
                                    make_async_byzantine_step,
                                    make_byzantine_step)

__all__ = ["AsyncByzantineTrainer", "ByzantineSpec", "ByzantineTrainer",
           "init_flat_agg_state", "init_flat_async_state",
           "make_async_byzantine_step", "make_byzantine_step"]
