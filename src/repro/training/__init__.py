from repro.training.trainer import (ByzantineSpec, ByzantineTrainer,
                                    init_flat_agg_state, make_byzantine_step)

__all__ = ["ByzantineSpec", "ByzantineTrainer", "init_flat_agg_state",
           "make_byzantine_step"]
