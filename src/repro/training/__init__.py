from repro.training.trainer import (ByzantineSpec, ByzantineTrainer,
                                    make_byzantine_step)

__all__ = ["ByzantineSpec", "ByzantineTrainer", "make_byzantine_step"]
