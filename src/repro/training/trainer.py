"""Byzantine distributed-SGD training engine (single-host reference).

Faithful to the paper's protocol (§2): each of n - f honest workers draws
an i.i.d. mini-batch and submits a stochastic gradient; the omniscient
adversary reads them and fabricates f Byzantine submissions; the master
aggregates with a GAR and updates the model.  Everything happens in-graph
(the adversary included) so a training step is one jit'd call.

The aggregation rule is resolved through the unified registry
(``repro.agg``); stateful rules (``buffered-*``,
``centered_clip_momentum``) thread an explicit ``AggState`` through the
step and the trainer loop, while stateless rules keep the historic
signatures untouched.

The *asynchronous* flat reference (``make_async_byzantine_step`` /
``AsyncByzantineTrainer``) drops the per-step barrier: submissions live
in a ``GradientBus`` (``repro.dist.async_train``) of per-worker
versioned slots, an in-graph delay schedule decides who delivers, and
the rule — typically a staleness-weighted ``stale-<base>`` — aggregates
the slot stack.  With ``spec.async_tau = 0`` the async step reproduces
the synchronous one exactly (see docs/async-runtime.md).

The mesh-sharded production variants live in ``repro.dist.train`` /
``repro.dist.async_train`` — this module is the semantics reference
they are tested against.
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.agg.specs import AggSpec
from repro.agg.state import AggState, init_state
from repro.agg.reputation import (DEFAULT_REP_DECAY, DEFAULT_REP_LR,
                                  reputation_scores, step_size_multiplier,
                                  update_reputation)
from repro.core import attacks as attacks_lib
from repro.core import pytree as pt
from repro.dist.async_train import (delivery_mask, init_bus, resolve_tau,
                                    staleness_excess, update_bus)
from repro.obs.buffer import drain
from repro.obs.schema import async_extras, core_metrics, selection_weight
from repro.optim import Optimizer

#: deprecation alias — the single-host spec is now the unified
#: ``repro.agg.AggSpec``; ``spec.validate()`` keeps reading
#: ``spec.n_workers`` as before.
ByzantineSpec = AggSpec


def init_flat_agg_state(spec: AggSpec, params,
                        n_rows: Optional[int] = None):
    """Zeroed ``AggState`` for a stateful GAR on the flat (n, d) path.

    Args:
      spec: the protocol spec; ``n_workers`` must be set (the flat path
        stacks all n submissions into one matrix).
      params: the parameter pytree — only the total coordinate count is
        read.
      n_rows: row count of the stacked matrix the rule will see —
        ``n_workers`` under attack, ``n_honest`` in clean mode
        (``None`` infers it from the spec's attack configuration).

    Returns:
      An ``AggState`` sized for the ``(n_rows, d)`` stacked matrix, or
      ``None`` when the rule is stateless.
    """
    rule = spec.rule()
    if not rule.stateful:
        return None
    if n_rows is None:
        n_rows = (spec.n_workers if spec.f > 0 and spec.attack != "none"
                  else spec.n_honest)
    d = sum(math.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    template = jax.ShapeDtypeStruct((n_rows, d), jnp.float32)
    return init_state(rule, template, flat=True)


def _flat_grad(grad_fn: Callable, params, batch) -> jnp.ndarray:
    """Flat ``(d,)`` gradient of one (clean auxiliary) batch, in the
    exact coordinate order of ``pt.stack_flatten`` (the scoring target
    must index the same space as the worker rows)."""
    clean = grad_fn(params, batch[0], batch[1])
    stacked = jax.tree_util.tree_map(lambda l: l[None], clean)
    flat, _ = pt.stack_flatten(stacked)
    return flat[0]


def make_byzantine_step(loss_fn: Callable, optimizer: Optimizer,
                        spec: ByzantineSpec,
                        attack_on: bool = True) -> Callable:
    """Build a jit-able training step.

    loss_fn(params, x, y) -> scalar loss.
    batch: x (n_honest, b, ...), y (n_honest, b, ...) — per-honest-worker.
    Returns step(params, opt_state, x, y, key) ->
        (params, opt_state, metrics dict); a stateful GAR appends an
    ``agg_state`` argument and return slot (carried by the caller).
    """
    spec.validate()
    rule = spec.rule()
    reputed = "reputation" in rule.state_fields
    attack = attacks_lib.get_attack(spec.attack) if attack_on else None
    akw = dict(spec.attack_kwargs)

    def run_step(params, opt_state, x, y, key, agg_state):
        grad_fn = jax.grad(loss_fn)
        worker_grads = jax.vmap(lambda xi, yi: grad_fn(params, xi, yi))(x, y)
        flat, ctx = pt.stack_flatten(worker_grads)      # (n_honest, d)

        if attack is not None and spec.f > 0:
            kw = dict(akw)
            if attack in (attacks_lib.omniscient_lp,
                          attacks_lib.omniscient_linf,
                          attacks_lib.reputation_burn):
                kw.setdefault("step", opt_state["step"])
            byz = attack(flat, spec.f, key, **kw)
            full = jnp.concatenate([flat, byz], axis=0)
        else:
            full = flat
        n_eff = full.shape[0]

        rep_prev = agg_state.reputation if reputed else None
        if rule.stateful:
            res, agg_state = rule.dense_fn(full, spec.f_declared, agg_state)
        else:
            res = rule.dense_fn(full, spec.f_declared)
        grad_out = res.gradient
        step_scale = jnp.ones((), jnp.float32)
        if reputed:
            if spec.aux_batch is not None:
                # ByGARS proper: re-score against the clean auxiliary
                # gradient (the aggregate itself can be owned by a
                # colluding majority), overriding the rule's own
                # agreement update of this step
                target = _flat_grad(grad_fn, params, spec.aux_batch)
                lr = (DEFAULT_REP_LR if spec.rep_lr is None
                      else spec.rep_lr)
                decay = (DEFAULT_REP_DECAY if spec.rep_decay is None
                         else spec.rep_decay)
                agg_state = agg_state._replace(
                    reputation=update_reputation(
                        rep_prev, reputation_scores(full, target),
                        lr, decay))
            if spec.rep_lr:
                # staleness-adaptive step size (Alistarh et al.)
                step_scale = step_size_multiplier(agg_state)
                grad_out = grad_out * step_scale
        agg = pt.unflatten(grad_out, ctx)
        new_params, new_state = optimizer.update(agg, opt_state, params)

        honest_mean = jnp.mean(flat, axis=0)
        metrics = core_metrics(
            loss=loss_fn(params, x[0], y[0]),
            byz_weight=selection_weight(res.selected, spec.n_honest),
            agg_dev=jnp.linalg.norm(res.gradient - honest_mean),
            grad_norm=jnp.linalg.norm(res.gradient),
            step_scale=step_scale if reputed else None)
        return new_params, new_state, metrics, agg_state

    if rule.stateful:
        return run_step

    def step(params, opt_state, x, y, key):
        return run_step(params, opt_state, x, y, key, None)[:3]

    return step


class ByzantineTrainer:
    """Convenience loop: data -> jit step -> metrics history.

    For stateful GARs the trainer owns the ``AggState``
    (``self.agg_state``), zero-initialized at construction and carried
    across ``run`` calls — the caller's loop stays unchanged.  When
    ``attack_until`` flips the protocol from attacked (n rows) to clean
    (n - f rows), per-worker history buffers no longer match the
    submission count and are re-initialized — the clean committee
    starts a fresh window; row-count-independent state (the
    ``centered_clip_momentum`` center) survives the flip.
    """

    def __init__(self, loss_fn, params, optimizer: Optimizer,
                 spec: ByzantineSpec, seed: int = 0):
        self.spec = spec
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self._rule = spec.rule()
        self._stateful = self._rule.stateful
        self._attack_mode = spec.f > 0 and spec.attack != "none"
        self.agg_state = init_flat_agg_state(spec, params)
        self._step_attacked = jax.jit(
            make_byzantine_step(loss_fn, optimizer, spec, attack_on=True))
        self._step_clean = jax.jit(
            make_byzantine_step(loss_fn, optimizer, spec, attack_on=False))
        self.key = jax.random.PRNGKey(seed)
        self.history: list = []

    def run(self, batcher, n_steps: int, attack_until: Optional[int] = None,
            eval_fn: Optional[Callable] = None, eval_every: int = 0,
            start_step: int = 0):
        for t in range(start_step, start_step + n_steps):
            x, y = batcher.batch(t)
            self.key, sub = jax.random.split(self.key)
            attacked = (attack_until is None) or (t < attack_until)
            use_attack = (attacked and self.spec.f > 0
                          and self.spec.attack != "none")
            fn = self._step_attacked if use_attack else self._step_clean
            if self._stateful and use_attack != self._attack_mode:
                self._attack_mode = use_attack
                # per-worker buffers are row-count-dependent: the
                # history window, the (n,) reputation column *and* the
                # (cap, n) forensics ring must restart when the
                # committee changes size; the row-count-independent
                # clipping center survives
                if ({"history", "reputation", "obs"}
                        & set(self._rule.state_fields)):
                    rows = (self.spec.n_workers if use_attack
                            else self.spec.n_honest)
                    self.agg_state = init_flat_agg_state(
                        self.spec, self.params, n_rows=rows)
            args = (self.params, self.opt_state, jnp.asarray(x),
                    jnp.asarray(y), sub)
            if self._stateful:
                self.params, self.opt_state, m, self.agg_state = fn(
                    *args, self.agg_state)
            else:
                self.params, self.opt_state, m = fn(*args)
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = t
            if eval_fn and eval_every and t % eval_every == 0:
                rec["eval_acc"] = float(eval_fn(self.params))
            self.history.append(rec)
        return self.history

    def telemetry(self):
        """Drain the carried aggregation-forensics ring to host numpy.

        Args:
          (none) — reads ``self.agg_state.obs``.

        Returns:
          ``repro.obs.buffer.drain``'s dict (``pushed`` / ``records`` /
          ``selection_frequency``); empty when the spec was built
          without ``telemetry=True``.
        """
        obs = self.agg_state.obs if self.agg_state is not None else ()
        return drain(obs)


# ---------------------------------------------------------------------------
# the asynchronous flat reference (GradientBus over the (n, d) matrix)
# ---------------------------------------------------------------------------

def init_flat_async_state(spec: AggSpec, params,
                          n_rows: Optional[int] = None) -> AggState:
    """Zeroed bus-carrying ``AggState`` for the flat async path.

    Unlike ``init_flat_agg_state`` this never returns ``None``: the
    async runtime always carries a state, because the ``GradientBus``
    itself is the asynchrony — stateless rules get ``step`` + bus only,
    stateful rules (``stale-*``, ``buffered-*``) their buffers too.

    Args:
      spec: the protocol spec; ``n_workers`` must be set.
      params: the parameter pytree — only the total coordinate count is
        read.
      n_rows: row count of the stacked matrix / bus — ``n_workers``
        under attack, ``n_honest`` in clean mode (``None`` infers it
        from the spec's attack configuration).

    Returns:
      An ``AggState`` whose ``bus`` holds a zero ``(n_rows, d)`` slot
      matrix with ``step = versions = 0``.
    """
    rule = spec.rule()
    if n_rows is None:
        n_rows = (spec.n_workers if spec.f > 0 and spec.attack != "none"
                  else spec.n_honest)
    d = sum(math.prod(p.shape) for p in jax.tree_util.tree_leaves(params))
    template = jax.ShapeDtypeStruct((n_rows, d), jnp.float32)
    if rule.stateful:
        state = init_state(rule, template, flat=True)
    else:
        state = AggState(step=jnp.zeros((), jnp.int32))
    if state.bus == ():
        state = state._replace(bus=init_bus(template))
    return state


def make_async_byzantine_step(loss_fn: Callable, optimizer: Optimizer,
                              spec: AggSpec) -> Callable:
    """Build the jit-able asynchronous flat training step.

    The single-host reference of ``repro.dist.async_train
    .make_async_train_step``: same ``GradientBus`` protocol over the
    flat ``(n, d)`` matrix.  All workers compute fresh gradients, the
    last f are rewritten by the configured attack (the delay-exploiting
    ``stale_replay`` / ``slow_drift`` read their previous bus rows), the
    delay schedule (``spec.async_tau`` / ``spec.async_schedule``)
    decides which honest workers deliver — Byzantine rows always do —
    and the registry rule aggregates the slot stack.

    Unlike ``make_byzantine_step`` there is no ``attack_on`` variant:
    the bus row count is baked into the carried state, so the lock-free
    protocol cannot re-synchronize mid-run (see
    :class:`AsyncByzantineTrainer`) — clean runs are expressed through
    the spec (``attack="none"`` or ``f=0``), which keeps the step's row
    count and :func:`init_flat_async_state`'s inference agreeing.

    Args:
      loss_fn: ``loss_fn(params, x, y) -> scalar``.
      optimizer: the ``repro.optim`` optimizer.
      spec: unified protocol spec (``n_workers`` set; async fields read).

    Returns:
      ``step(params, opt_state, x, y, key, agg_state) -> (params,
      opt_state, metrics, agg_state)`` — always the stateful signature;
      size the carried state with :func:`init_flat_async_state`.  With
      ``spec.async_tau = 0`` the step reproduces
      ``make_byzantine_step`` bitwise on identical inputs.
    """
    spec.validate()
    rule = spec.rule()
    reputed = "reputation" in rule.state_fields
    attack = attacks_lib.get_attack(spec.attack)
    akw = dict(spec.attack_kwargs)
    delay_attacks = (attacks_lib.stale_replay, attacks_lib.slow_drift)

    def step(params, opt_state, x, y, key, agg_state):
        grad_fn = jax.grad(loss_fn)
        worker_grads = jax.vmap(lambda xi, yi: grad_fn(params, xi, yi))(x, y)
        flat, ctx = pt.stack_flatten(worker_grads)      # (n_honest, d)
        t = agg_state.step
        n_h = spec.n_honest

        attacked = attack is not None and spec.f > 0
        if attacked:
            kw = dict(akw)
            if attack in (attacks_lib.omniscient_lp,
                          attacks_lib.omniscient_linf,
                          attacks_lib.reputation_burn):
                kw.setdefault("step", opt_state["step"])
            if attack in delay_attacks:
                kw.setdefault("prev", agg_state.bus.grads[n_h:])
                kw.setdefault("step", t)
            byz = attack(flat, spec.f, key, **kw)
            full = jnp.concatenate([flat, byz], axis=0)
        else:
            full = flat
        n_eff = full.shape[0]

        tau = resolve_tau(spec.async_tau, n_eff)
        deliver = delivery_mask(t, agg_state.bus.versions, tau,
                                schedule=spec.async_schedule,
                                seed=spec.seed)
        if attacked:
            deliver = deliver | (jnp.arange(n_eff) >= n_h)
        bus = update_bus(agg_state.bus, full, t, deliver)
        state_in = agg_state._replace(bus=bus)

        rep_prev = agg_state.reputation if reputed else None
        if rule.stateful:
            res, new_state = rule.dense_fn(bus.grads, spec.f_declared,
                                           state_in)
        else:
            res = rule.dense_fn(bus.grads, spec.f_declared)
            new_state = state_in._replace(step=t + 1)
        grad_out = res.gradient
        step_scale = jnp.ones((), jnp.float32)
        if reputed:
            if spec.aux_batch is not None:
                # score the slot stack (what was aggregated) against the
                # clean auxiliary gradient — ByGARS proper
                target = _flat_grad(grad_fn, params, spec.aux_batch)
                lr = (DEFAULT_REP_LR if spec.rep_lr is None
                      else spec.rep_lr)
                decay = (DEFAULT_REP_DECAY if spec.rep_decay is None
                         else spec.rep_decay)
                new_state = new_state._replace(
                    reputation=update_reputation(
                        rep_prev, reputation_scores(bus.grads, target),
                        lr, decay))
            if spec.rep_lr:
                step_scale = step_size_multiplier(new_state)
                grad_out = grad_out * step_scale
        agg = pt.unflatten(grad_out, ctx)
        new_params, new_opt = optimizer.update(agg, opt_state, params)

        honest_mean = jnp.mean(bus.grads[:n_h], axis=0)
        staleness = t - bus.versions
        metrics = core_metrics(
            loss=loss_fn(params, x[0], y[0]),
            byz_weight=selection_weight(res.selected, n_h),
            agg_dev=jnp.linalg.norm(res.gradient - honest_mean),
            grad_norm=jnp.linalg.norm(res.gradient),
            step_scale=step_scale if reputed else None)
        metrics.update(async_extras(staleness,
                                    staleness_excess(bus, t, tau),
                                    deliver))
        return new_params, new_opt, metrics, new_state

    return step


class AsyncByzantineTrainer:
    """Convenience loop for the asynchronous runtime (flat reference).

    Mirrors :class:`ByzantineTrainer` but drives
    :func:`make_async_byzantine_step`: the trainer owns the carried
    ``AggState`` — whose ``bus`` holds every worker's versioned slot —
    zero-initialized at construction and threaded across ``run`` calls.
    There is no ``attack_until`` switch: the bus row count is fixed at
    construction (n under attack, n_honest clean), matching the
    lock-free protocol where the committee never re-synchronizes.
    """

    def __init__(self, loss_fn, params, optimizer: Optimizer,
                 spec: AggSpec, seed: int = 0):
        self.spec = spec
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self.agg_state = init_flat_async_state(spec, params)
        self._step = jax.jit(
            make_async_byzantine_step(loss_fn, optimizer, spec))
        self.key = jax.random.PRNGKey(seed)
        self.history: list = []

    def run(self, batcher, n_steps: int,
            eval_fn: Optional[Callable] = None, eval_every: int = 0,
            start_step: int = 0):
        """Drive the jitted async step for ``n_steps`` (see
        :meth:`ByzantineTrainer.run` for the loop contract).

        Args:
          batcher: per-honest-worker batch source (``batcher.batch(t)``).
          n_steps: number of async steps to run.
          eval_fn: optional ``params -> accuracy`` probe.
          eval_every: evaluation period (0 = never).
          start_step: first step index (continuation support).

        Returns:
          The accumulated metrics history (list of per-step dicts).
        """
        for t in range(start_step, start_step + n_steps):
            x, y = batcher.batch(t)
            self.key, sub = jax.random.split(self.key)
            (self.params, self.opt_state, m,
             self.agg_state) = self._step(self.params, self.opt_state,
                                          jnp.asarray(x), jnp.asarray(y),
                                          sub, self.agg_state)
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = t
            if eval_fn and eval_every and t % eval_every == 0:
                rec["eval_acc"] = float(eval_fn(self.params))
            self.history.append(rec)
        return self.history

    def telemetry(self):
        """Drain the carried aggregation-forensics ring to host numpy.

        Args:
          (none) — reads ``self.agg_state.obs``.

        Returns:
          ``repro.obs.buffer.drain``'s dict; empty when the spec was
          built without ``telemetry=True``.
        """
        return drain(self.agg_state.obs)
