"""Byzantine distributed-SGD training engine (single-host reference).

Faithful to the paper's protocol (§2): each of n - f honest workers draws
an i.i.d. mini-batch and submits a stochastic gradient; the omniscient
adversary reads them and fabricates f Byzantine submissions; the master
aggregates with a GAR and updates the model.  Everything happens in-graph
(the adversary included) so a training step is one jit'd call.

The mesh-sharded production variant lives in ``repro.dist.train`` — this
module is the semantics reference it is tested against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as attacks_lib
from repro.core import gars as gars_lib
from repro.core import pytree as pt
from repro.optim import Optimizer


@dataclasses.dataclass(frozen=True)
class ByzantineSpec:
    n_workers: int                  # total n = honest + byzantine
    f: int                          # byzantine count (and GAR's bound)
    gar: str = "bulyan-krum"
    attack: str = "none"
    attack_kwargs: tuple = ()       # (("gamma", 10.0), ...)
    declared_f: Optional[int] = None  # f the master *assumes* (>= actual)

    @property
    def n_honest(self) -> int:
        return self.n_workers - self.f

    @property
    def f_declared(self) -> int:
        return self.declared_f if self.declared_f is not None else self.f

    def validate(self) -> None:
        need = gars_lib.quorum(self.gar, self.f_declared)
        if self.n_workers < need:
            raise ValueError(
                f"{self.gar} needs n >= {need} for f={self.f_declared}, "
                f"got n={self.n_workers}")


def make_byzantine_step(loss_fn: Callable, optimizer: Optimizer,
                        spec: ByzantineSpec,
                        attack_on: bool = True) -> Callable:
    """Build a jit-able training step.

    loss_fn(params, x, y) -> scalar loss.
    batch: x (n_honest, b, ...), y (n_honest, b, ...) — per-honest-worker.
    Returns step(params, opt_state, x, y, key) ->
        (params, opt_state, metrics dict).
    """
    spec.validate()
    gar = gars_lib.get_gar(spec.gar)
    attack = attacks_lib.get_attack(spec.attack) if attack_on else None
    akw = dict(spec.attack_kwargs)

    def step(params, opt_state, x, y, key):
        grad_fn = jax.grad(loss_fn)
        worker_grads = jax.vmap(lambda xi, yi: grad_fn(params, xi, yi))(x, y)
        flat, ctx = pt.stack_flatten(worker_grads)      # (n_honest, d)

        if attack is not None and spec.f > 0:
            kw = dict(akw)
            if attack in (attacks_lib.omniscient_lp,
                          attacks_lib.omniscient_linf):
                kw.setdefault("step", opt_state["step"])
            byz = attack(flat, spec.f, key, **kw)
            full = jnp.concatenate([flat, byz], axis=0)
        else:
            full = flat
        n_eff = full.shape[0]

        res = gar(full, spec.f_declared)
        agg = pt.unflatten(res.gradient, ctx)
        new_params, new_state = optimizer.update(agg, opt_state, params)

        honest_mean = jnp.mean(flat, axis=0)
        metrics = {
            "loss": loss_fn(params, x[0], y[0]),
            "byz_weight": jnp.sum(res.selected[spec.n_honest:])
            if n_eff > spec.n_honest else jnp.zeros(()),
            "agg_dev": jnp.linalg.norm(res.gradient - honest_mean),
            "grad_norm": jnp.linalg.norm(res.gradient),
        }
        return new_params, new_state, metrics

    return step


class ByzantineTrainer:
    """Convenience loop: data -> jit step -> metrics history."""

    def __init__(self, loss_fn, params, optimizer: Optimizer,
                 spec: ByzantineSpec, seed: int = 0):
        self.spec = spec
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self._step_attacked = jax.jit(
            make_byzantine_step(loss_fn, optimizer, spec, attack_on=True))
        self._step_clean = jax.jit(
            make_byzantine_step(loss_fn, optimizer, spec, attack_on=False))
        self.key = jax.random.PRNGKey(seed)
        self.history: list = []

    def run(self, batcher, n_steps: int, attack_until: Optional[int] = None,
            eval_fn: Optional[Callable] = None, eval_every: int = 0,
            start_step: int = 0):
        for t in range(start_step, start_step + n_steps):
            x, y = batcher.batch(t)
            self.key, sub = jax.random.split(self.key)
            attacked = (attack_until is None) or (t < attack_until)
            fn = self._step_attacked if (attacked and self.spec.f > 0
                                         and self.spec.attack != "none"
                                         ) else self._step_clean
            self.params, self.opt_state, m = fn(
                self.params, self.opt_state, jnp.asarray(x), jnp.asarray(y),
                sub)
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = t
            if eval_fn and eval_every and t % eval_every == 0:
                rec["eval_acc"] = float(eval_fn(self.params))
            self.history.append(rec)
        return self.history
