"""gemma3-1b [dense] — 5:1 local(sliding-window):global attention, 128k+
context, MQA (kv=1), head_dim=256, huge vocab.  [hf:google/gemma-3-1b-pt]

26 layers = 4 full (5 local + 1 global) periods + 2 tail local layers.
"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = True  # 5/6 layers have bounded (window=512) KV; batch=1
                      # global layers decode linearly in S


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", arch_type="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
        d_ff=6912, vocab_size=262144, head_dim=256,
        ffn_act="geglu",
        layer_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
        window=512, rope_theta=1e6,
        tie_embeddings=True, attn_shard="batch", param_dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-reduced", arch_type="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
        d_ff=512, vocab_size=1024, head_dim=64,
        ffn_act="geglu", layer_pattern=("swa", "attn"), window=64,
        tie_embeddings=True, param_dtype="float32",
    )
