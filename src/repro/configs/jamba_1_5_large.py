"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave (one
attention layer per 8-layer block), MoE 16e top-2 on every other layer.
[arXiv:2403.19887]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = True  # 7/8 of layers are constant-state mamba; batch=1 KV


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", arch_type="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab_size=65536, head_dim=128,
        ffn_act="swiglu",
        layer_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        moe_impl="scatter", moe_experts=16, moe_top_k=2, moe_every=2, moe_offset=1,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        tie_embeddings=False, attn_shard="batch", param_dtype="bfloat16",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-reduced", arch_type="hybrid",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=1024, head_dim=32,
        ffn_act="swiglu", layer_pattern=("mamba", "attn"),
        moe_experts=4, moe_top_k=2, moe_every=2, moe_offset=1,
        ssm_state=32, ssm_head_dim=32, ssm_expand=2, ssm_conv=4,
        tie_embeddings=False, param_dtype="float32",
    )
