"""llama-3.2-vision-11b [vlm] — language decoder with cross-attention image
layers every 5th layer; the ViT tower + projector are STUBBED (input_specs
provides (B, 1601, d_model) projected patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = False  # full attention + cross-attn -> skip long_500k


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", arch_type="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, head_dim=128,
        ffn_act="swiglu",
        layer_pattern=("xattn", "attn", "attn", "attn", "attn"),
        vision_seq=1601,
        rope_theta=500000.0, tie_embeddings=False, attn_shard="batch", param_dtype="bfloat16",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-vision-reduced", arch_type="vlm",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=1024, head_dim=32,
        ffn_act="swiglu", layer_pattern=("xattn", "attn"), vision_seq=16,
        tie_embeddings=False, param_dtype="float32",
    )
