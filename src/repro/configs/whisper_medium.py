"""whisper-medium [audio] — encoder-decoder; conv/mel frontend is STUBBED
(input_specs provides (B, 1500, d_model) frame embeddings).  Every decoder
layer cross-attends to the encoder output.  [arXiv:2212.04356]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = False  # real decoder context is 448; 500k decode meaningless


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", arch_type="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865,
        ffn_act="gelu", qkv_bias=True, layer_pattern=("xattn",),
        encoder_layers=24, encoder_seq=1500,
        tie_embeddings=True, attn_shard="batch", param_dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-reduced", arch_type="audio",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=1024,
        ffn_act="gelu", qkv_bias=True, layer_pattern=("xattn",),
        encoder_layers=2, encoder_seq=64,
        tie_embeddings=True, param_dtype="float32",
    )
