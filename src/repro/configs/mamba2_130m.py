"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = True  # constant-size SSM state


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", arch_type="ssm",
        n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=0, vocab_size=50280,
        layer_pattern=("mamba",),
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        tie_embeddings=True, attn_shard="batch", param_dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-reduced", arch_type="ssm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=1024,
        layer_pattern=("mamba",),
        ssm_state=32, ssm_head_dim=32, ssm_expand=2, ssm_conv=4,
        tie_embeddings=True, param_dtype="float32",
    )
