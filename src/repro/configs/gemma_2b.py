"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1). [arXiv:2403.08295]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = False  # pure full attention -> skip long_500k (DESIGN.md §6)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", arch_type="dense",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab_size=256000, head_dim=256,
        ffn_act="geglu", layer_pattern=("attn",),
        tie_embeddings=True, attn_shard="batch", param_dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-reduced", arch_type="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
        d_ff=512, vocab_size=1024, head_dim=64,
        ffn_act="geglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )
