"""qwen1.5-4b [dense] — QKV bias, full MHA (kv=20). [hf:Qwen/Qwen1.5-0.5B]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = False  # pure full attention -> skip long_500k (DESIGN.md §6)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", arch_type="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
        d_ff=6912, vocab_size=151936, head_dim=128,
        ffn_act="swiglu", qkv_bias=True, layer_pattern=("attn",),
        tie_embeddings=True, attn_shard="batch", param_dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b-reduced", arch_type="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=1024, head_dim=64,
        ffn_act="swiglu", qkv_bias=True, layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )
