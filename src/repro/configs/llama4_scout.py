"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + 1 shared expert,
iRoPE layout (3 chunked-local RoPE layers : 1 global NoPE layer), early
fusion (vision tokens stubbed as pre-projected embeddings in the stream).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = True  # 3/4 layers chunk-local (8192) KV; global NoPE
                      # layers decode linearly in S at batch=1


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", arch_type="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048, head_dim=128,
        ffn_act="swiglu",
        layer_pattern=("chunked", "chunked", "chunked", "attn_nope"),
        chunk=8192,
        moe_impl="scatter", moe_experts=16, moe_top_k=1, moe_every=1, moe_shared=1,
        rope_theta=500000.0, tie_embeddings=False, attn_shard="batch", param_dtype="bfloat16",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-reduced", arch_type="moe",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=1024, head_dim=32,
        ffn_act="swiglu", layer_pattern=("chunked", "attn_nope"), chunk=64,
        moe_experts=4, moe_top_k=1, moe_every=1, moe_shared=1,
        tie_embeddings=False, param_dtype="float32",
    )
