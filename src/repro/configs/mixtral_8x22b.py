"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = True  # sliding-window attention bounds the KV cache


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", arch_type="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        ffn_act="swiglu", layer_pattern=("swa",), window=4096,
        moe_impl="scatter", moe_experts=8, moe_top_k=2, moe_every=1,
        rope_theta=1e6, tie_embeddings=False, attn_shard="batch", param_dtype="bfloat16",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-reduced", arch_type="moe",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=1024, head_dim=32,
        ffn_act="swiglu", layer_pattern=("swa",), window=64,
        moe_experts=4, moe_top_k=2, moe_every=1,
        tie_embeddings=False, param_dtype="float32",
    )
