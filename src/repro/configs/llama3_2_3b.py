"""llama3.2-3b [dense] — small llama3, GQA kv=8.
[hf:meta-llama/Llama-3.2-1B]"""
from repro.models.config import ModelConfig

SUPPORTS_LONG = False  # pure full attention -> skip long_500k (DESIGN.md §6)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", arch_type="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab_size=128256, head_dim=128,
        ffn_act="swiglu", layer_pattern=("attn",),
        rope_theta=500000.0, tie_embeddings=True, attn_shard="batch", param_dtype="float32",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-reduced", arch_type="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=1024, head_dim=32,
        ffn_act="swiglu", layer_pattern=("attn",),
        tie_embeddings=True, param_dtype="float32",
    )
