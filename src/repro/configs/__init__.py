"""Assigned architecture configs (``--arch <id>``).

Each module defines ``config()`` — the exact assigned full-size
architecture (citation in its docstring) — and ``reduced()`` — a tiny
same-family variant (<= 2-layer-ish, d_model <= 512, <= 4 experts, small
vocab) for CPU smoke tests.  ``SUPPORTS_LONG`` marks architectures that run
the long_500k decode shape (sub-quadratic / bounded-KV; see DESIGN.md §6).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "mixtral_8x22b",
    "mamba2_130m",
    "jamba_1_5_large",
    "gemma_2b",
    "whisper_medium",
    "llama3_2_3b",
    "qwen1_5_4b",
    "gemma3_1b",
    "llama4_scout",
    "llama3_2_vision",
]

# canonical assignment ids -> module names
ALIASES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-130m": "mamba2_130m",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "gemma-2b": "gemma_2b",
    "whisper-medium": "whisper_medium",
    "llama3.2-3b": "llama3_2_3b",
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma3-1b": "gemma3_1b",
    "llama4-scout-17b-a16e": "llama4_scout",
    "llama-3.2-vision-11b": "llama3_2_vision",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def supports_long(arch: str) -> bool:
    return getattr(_module(arch), "SUPPORTS_LONG", False)


def shape_applicable(arch: str, shape: str) -> bool:
    """DESIGN.md §6: long_500k only for sub-quadratic/bounded-KV archs."""
    if shape == "long_500k":
        return supports_long(arch)
    return True
