"""The asynchronous bounded-staleness Byzantine train step.

The third runtime mode (train / serve / **async-train**): instead of the
synchronous barrier of ``repro.dist.train`` — every worker submits a
fresh gradient every step — the master aggregates whatever a
:class:`GradientBus` holds.  The bus is a jit-able pytree of per-worker
*versioned gradient slots*: a pytree of ``(n, *dims)`` leaves mirroring
the gradient tree, plus ``(n,)`` int32 ``versions`` (the step each
slot's gradient was computed at — hence against which parameters) and
``arrival_step`` (the step the master observed the write) arrays.

Arrival is simulated by an in-graph deterministic *delay schedule* with
per-worker bounded staleness ``tau_w`` (heterogeneous; Byzantine workers
additionally control their own arrival, see below): at global step t a
worker whose schedule fires recomputes its gradient at the *current*
parameters and overwrites its slot with ``versions[w] = t``; everyone
else's slot keeps the gradient it computed against older parameters.
One jitted step therefore simulates lock-free arrival on both the
single-device and the GSPMD-sharded mesh path — exactly like the sync
step, sharding enters only through the input/output shardings (the bus
slots shard like the worker-stacked gradients they mirror).

Aggregation goes through the unchanged ``repro.agg`` registry.  The
``stale-<base>`` rules (``repro.agg.staleness``) read per-worker
staleness ``t - versions`` from the :class:`~repro.agg.state.AggState`
— extended to carry the bus — and reweight the stack before any base
rule; plain rules aggregate the raw slots.  ``init_async_state`` is
``jax.eval_shape``-composable, so the 512-device dry-run lowers
``--async-tau N --gar stale-*`` abstractly.

Threat model: the delay schedule only binds *honest* workers.  A
Byzantine worker controls its own arrival — under an active attack the
last f workers deliver every step and stamp fresh versions (staleness
weighting cannot see through a lying timestamp; that is the point of
the ``stale_replay`` / ``slow_drift`` attacks, whose content exploits
the leeway staleness opens while *looking* fresh — the robust base rule
has to cut them by geometry).  With ``async_tau=0`` every honest worker
delivers every step and the async step reproduces
``repro.dist.train.make_train_step`` exactly (pinned by
``tests/test_async_train.py``).

The flat single-host reference of this runtime lives in
``repro.training.trainer`` (``make_async_byzantine_step`` /
``AsyncByzantineTrainer``); architecture notes in docs/async-runtime.md.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.specs import AggSpec
from repro.agg.state import AggState, init_state
from repro.dist.robust import distributed_aggregate, inject_byzantine
from repro.dist.train import make_loss_fn
from repro.obs.schema import (async_extras, core_metrics, global_norm,
                              selection_weight)
from repro.optim import Optimizer

__all__ = ["GradientBus", "delivery_mask", "init_async_state", "init_bus",
           "make_async_train_step", "resolve_tau", "staleness_excess",
           "update_bus"]


class GradientBus(NamedTuple):
    """Per-worker versioned gradient slots (a jit-able pytree).

    grads:         pytree of ``(n, *dims)`` slot leaves — worker w's row
                   holds the gradient it last delivered, computed against
                   the parameters of step ``versions[w]``.
    versions:      ``(n,)`` int32 — compute step of each slot's gradient
                   (staleness at aggregation step t is ``t - versions``).
    arrival_step:  ``(n,)`` int32 — step the master last observed a
                   write into each slot (equals ``versions`` for honest
                   workers; a Byzantine worker may stamp a fresh version
                   on stale content, so the two can diverge in spirit —
                   the master can only ever observe arrival).
    """

    grads: Any
    versions: jnp.ndarray
    arrival_step: jnp.ndarray


def init_bus(template: Any) -> GradientBus:
    """Zeroed :class:`GradientBus` sized from a worker-stacked template.

    Args:
      template: pytree of ``(n, *dims)`` worker-stacked leaves (or
        ``jax.ShapeDtypeStruct`` leaves — only shapes/dtypes are read,
        so this composes with ``jax.eval_shape``).  A bare ``(n, d)``
        array is a valid single-leaf pytree (the flat-path layout).

    Returns:
      A bus with zero slots mirroring the template's structure and
      dtypes, and ``versions = arrival_step = 0`` — every delay
      schedule delivers all workers at step 0, so the zero slots are
      never aggregated.
    """
    leaves = jax.tree_util.tree_leaves(template)
    if not leaves:
        raise ValueError("empty bus template")
    n = leaves[0].shape[0]
    grads = jax.tree_util.tree_map(
        lambda leaf: jnp.zeros(leaf.shape, leaf.dtype), template)
    return GradientBus(grads=grads,
                       versions=jnp.zeros((n,), jnp.int32),
                       arrival_step=jnp.zeros((n,), jnp.int32))


def resolve_tau(tau: Any, n: int) -> jnp.ndarray:
    """Normalize a staleness bound to a per-worker ``(n,)`` int32 array.

    Args:
      tau: a non-negative int (homogeneous bound) or a length-n sequence
        of per-worker bounds (heterogeneous — e.g. fast pod-local
        workers at 0, cross-region stragglers at 8).
      n: worker count.

    Returns:
      ``(n,)`` int32 staleness bounds.  Raises ``ValueError`` for any
      negative bound (scalar or per-worker) or a sequence of the wrong
      length.  ``tau`` is static configuration — it must be concrete at
      trace time (the schedule's cycle length divides by ``tau + 1``).
    """
    if isinstance(tau, int):
        if tau < 0:
            raise ValueError(f"async_tau must be >= 0, got {tau}")
        return jnp.full((n,), tau, jnp.int32)
    arr = np.asarray(tau, dtype=np.int32)
    if arr.ndim == 0:
        arr = np.full((n,), int(arr), np.int32)
    if arr.shape != (n,):
        raise ValueError(
            f"per-worker async_tau needs shape ({n},), got {arr.shape}")
    if (arr < 0).any():
        raise ValueError(f"async_tau must be >= 0, got {tau!r}")
    return jnp.asarray(arr)


def delivery_mask(step, versions: jnp.ndarray, tau: jnp.ndarray,
                  schedule: str = "fixed", seed: int = 0) -> jnp.ndarray:
    """In-graph deterministic arrival mask for one async step.

    Args:
      step: () int32 global async step (traced).
      versions: ``(n,)`` int32 current slot versions (consulted by the
        ``random`` schedule's staleness-bound enforcement).
      tau: ``(n,)`` int32 per-worker staleness bounds (``resolve_tau``).
      schedule: ``"fixed"`` — staggered round-robin, worker w delivers
        when ``(step - w mod (tau_w+1)) % (tau_w + 1) == 0`` so same-tau
        workers spread their arrivals over the cycle; ``"random"`` —
        Bernoulli(1 / (tau_w + 1)) from a counter-based PRNG
        (``fold_in(seed, step)``), with delivery forced whenever the
        slot would otherwise exceed its bound.  Both schedules force
        delivery at step 0, so the zero-initialized bus never leaks
        into an aggregation.

    Returns:
      ``(n,)`` bool — True where worker w delivers a fresh gradient this
      step.  ``tau = 0`` yields all-True under both schedules (the
      synchronous special case).
    """
    n = versions.shape[0]
    step = jnp.asarray(step, jnp.int32)
    cycle = tau + 1
    if schedule == "fixed":
        phase = jnp.arange(n, dtype=jnp.int32) % cycle
        mask = (step - phase) % cycle == 0
    elif schedule == "random":
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        r = jax.random.uniform(key, (n,))
        mask = r * cycle.astype(jnp.float32) < 1.0
        mask = mask | ((step - versions) >= tau)
    else:
        raise ValueError(
            f"async_schedule must be 'fixed' or 'random', got "
            f"{schedule!r}")
    return mask | (step == 0)


def update_bus(bus: GradientBus, grads: Any, step,
               deliver: jnp.ndarray) -> GradientBus:
    """Write delivering workers' fresh gradients into their slots.

    Args:
      bus: the current bus.
      grads: pytree of ``(n, *dims)`` freshly computed gradients (same
        structure as ``bus.grads``).
      step: () int32 global async step — stamped as the version of every
        delivered slot.
      deliver: ``(n,)`` bool arrival mask (``delivery_mask``).

    Returns:
      The new bus: delivered rows overwritten (dtype-preserving
      ``where`` select), everyone else's slot, version and arrival
      untouched.  With an all-True mask the slot contents equal
      ``grads`` exactly — the bitwise anchor of the tau=0 sync
      equivalence.
    """
    def sel(old, new):
        m = deliver.reshape(deliver.shape + (1,) * (new.ndim - 1))
        return jnp.where(m, new.astype(old.dtype), old)

    step = jnp.asarray(step, jnp.int32)
    return GradientBus(
        grads=jax.tree_util.tree_map(sel, bus.grads, grads),
        versions=jnp.where(deliver, step, bus.versions),
        arrival_step=jnp.where(deliver, step, bus.arrival_step))


def staleness_excess(bus: GradientBus, step, tau: jnp.ndarray) -> jnp.ndarray:
    """Per-worker overshoot of the declared staleness bound.

    The bounded-staleness contract — every delay schedule must keep each
    honest worker's slot age at or below its ``tau_w`` — is exactly the
    kind of threshold invariant real Byzantine-tolerant systems break
    silently (the motivation of ``repro.audit``).  This helper makes the
    bound *observable*: the async step emits ``max(excess)`` as the
    ``staleness_excess`` metric every step, and the audit sweep asserts
    it stays 0 across the whole (tau, schedule) grid.

    Args:
      bus: the post-update bus of the current step.
      step: () int32 global async step the bus was just updated at.
      tau: ``(n,)`` int32 per-worker bounds (``resolve_tau``).

    Returns:
      ``(n,)`` int32 ``max(0, (step - versions) - tau)`` — 0 everywhere
      when the contract holds (a lying Byzantine version stamp shows up
      as 0 too: the master can only observe the stamped age).
    """
    staleness = jnp.asarray(step, jnp.int32) - bus.versions
    return jnp.maximum(staleness - tau, 0)


def init_async_state(spec: AggSpec, params: Any, n_workers: int) -> AggState:
    """Zeroed ``AggState`` carrying the bus for the async sharded path.

    Unlike the synchronous ``init_agg_state`` — which returns ``None``
    for stateless rules — the async runtime *always* carries a state:
    the bus itself is the asynchrony.  Rules with their own state
    (``stale-*``, ``buffered-*``, ``centered_clip_momentum``) get their
    buffers allocated alongside; plain rules get only ``step`` + bus.

    Args:
      spec: the protocol spec (``gar`` / ``history_window`` select the
        rule; ``attack``/``f`` size the bus for all n workers).
      params: the parameter pytree (or a ``ShapeDtypeStruct`` tree —
        only shapes are read, so this composes with ``jax.eval_shape``).
      n_workers: worker count, the leading axis of the gradient stacks.

    Returns:
      An ``AggState`` whose ``bus`` holds zero ``(n_workers, *dims)``
      slots in the parameter dtypes, with ``step = versions = 0``.
    """
    rule = spec.rule()
    template = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct((n_workers,) + tuple(p.shape),
                                       p.dtype), params)
    if rule.stateful:
        state = init_state(rule, template, flat=False)
    else:
        state = AggState(step=jnp.zeros((), jnp.int32))
    if state.bus == ():
        state = state._replace(bus=init_bus(template))
    return state


def make_async_train_step(cfg, spec: AggSpec, optimizer: Optimizer,
                          impl: str = "auto", mesh=None) -> Callable:
    """Build the jit-able asynchronous sharded Byzantine train step.

    The step always has the stateful signature ``step(params, opt_state,
    batch, agg_state) -> (params, opt_state, metrics, agg_state)`` —
    the carried ``AggState`` holds the :class:`GradientBus` (plus the
    rule's own buffers when ``spec.gar`` is stateful); size it with
    ``init_async_state``.  ``batch`` has the synchronous layout
    (``{"tokens", "labels"[, "extra"]}`` with a leading worker axis).

    Per step: all n workers compute fresh gradients against the current
    parameters (vmap — the simulation pays the sync compute so that
    every *delivered* gradient is genuinely evaluated at the parameters
    of its version step); under an attack the last f rows are rewritten
    in-graph (the delay-exploiting ``stale_replay`` / ``slow_drift``
    additionally read their previous slots); the delay schedule decides
    who delivers (Byzantine rows always do); the bus absorbs the
    deliveries; the registry rule aggregates the slot stack.

    With ``spec.async_tau = 0`` and the same spec this reproduces
    ``repro.dist.train.make_train_step`` bitwise on identical inputs.

    Args:
      cfg: the ``ModelConfig`` (drives the per-worker forward/backward).
      spec: unified protocol spec; reads ``async_tau`` /
        ``async_schedule`` on top of the synchronous fields.
      optimizer: the ``repro.optim`` optimizer applied to the aggregate.
      impl: attention implementation forwarded to the model.
      mesh: optional device mesh, consulted only by the Pallas distance
        backend (as in the synchronous step).

    Returns:
      The jit-able 4-ary step function.
    """
    loss_fn = make_loss_fn(cfg, impl)
    vg = jax.value_and_grad(loss_fn)
    rule = spec.rule()
    stateful = rule.stateful
    reputed = "reputation" in rule.state_fields

    def step(params, opt_state, batch, agg_state):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = batch.get("extra")
        n = tokens.shape[0]
        spec.validate(n, distributed=True)
        f = spec.f
        n_h = n - f
        tau = resolve_tau(spec.async_tau, n)
        t = agg_state.step

        if extra is None:
            losses, grads = jax.vmap(
                lambda tk, l: vg(params, tk, l))(tokens, labels)
        else:
            losses, grads = jax.vmap(
                lambda tk, l, e: vg(params, tk, l, e))(tokens, labels,
                                                       extra)

        attacked = spec.attack != "none" and f > 0
        if attacked:
            key = jax.random.fold_in(jax.random.PRNGKey(spec.seed),
                                     opt_state["step"])
            akw = dict(spec.attack_kwargs)
            akw.setdefault("gar_name", spec.gar)
            if spec.attack in ("stale_replay", "slow_drift"):
                akw.setdefault("prev", jax.tree_util.tree_map(
                    lambda l: l[n_h:], agg_state.bus.grads))
            grads = inject_byzantine(grads, f, spec.attack, key=key,
                                     step=t, **akw)

        deliver = delivery_mask(t, agg_state.bus.versions, tau,
                                schedule=spec.async_schedule,
                                seed=spec.seed)
        if attacked:
            # Byzantine workers control their own arrival: deliver every
            # step, stamping a fresh version on adversarial content
            deliver = deliver | (jnp.arange(n) >= n_h)
        bus = update_bus(agg_state.bus, grads, t, deliver)
        state_in = agg_state._replace(bus=bus)

        out = distributed_aggregate(
            bus.grads, spec.f_declared, spec.effective_gar,
            agg_dtype=spec.agg_dtype,
            distance_backend=spec.distance_backend, mesh=mesh,
            state=state_in if stateful else None,
            history_window=spec.history_window,
            rep_lr=spec.rep_lr, rep_decay=spec.rep_decay)
        if stateful:
            agg, res, new_state = out
        else:
            agg, res = out
            new_state = state_in._replace(step=t + 1)

        step_scale = jnp.ones((), jnp.float32)
        if reputed:
            from repro.agg.reputation import (
                DEFAULT_REP_DECAY, DEFAULT_REP_LR, step_size_multiplier,
                tree_reputation_scores, update_reputation)
            if spec.aux_batch is not None:
                # score the *slot* stack (what was aggregated) against
                # the clean auxiliary gradient — ByGARS proper
                aux = tuple(spec.aux_batch)
                _, clean = vg(params, *aux)
                scores = tree_reputation_scores(
                    jax.tree_util.tree_leaves(bus.grads),
                    jax.tree_util.tree_leaves(clean))
                lr = (DEFAULT_REP_LR if spec.rep_lr is None
                      else spec.rep_lr)
                decay = (DEFAULT_REP_DECAY if spec.rep_decay is None
                         else spec.rep_decay)
                new_state = new_state._replace(
                    reputation=update_reputation(
                        agg_state.reputation, scores, lr, decay))
            if spec.rep_lr:
                # the staleness-adaptive step-size tail (Alistarh et
                # al.): carried trust shrinks the applied update
                step_scale = step_size_multiplier(new_state)
                agg = jax.tree_util.tree_map(
                    lambda a: (a.astype(jnp.float32)
                               * step_scale).astype(a.dtype), agg)
        new_params, new_opt = optimizer.update(agg, opt_state, params)

        honest_mean = jax.tree_util.tree_map(
            lambda g: jnp.mean(g[:n_h].astype(jnp.float32), axis=0),
            bus.grads)
        dev = jax.tree_util.tree_map(
            lambda a, m: a.astype(jnp.float32) - m, agg, honest_mean)
        staleness = t - bus.versions
        metrics = core_metrics(
            loss=jnp.mean(losses[:n_h]),
            grad_norm=global_norm(agg),
            agg_dev=global_norm(dev),
            byz_weight=selection_weight(res.selected, n_h),
            step_scale=step_scale if reputed else None)
        metrics.update(async_extras(staleness,
                                    staleness_excess(bus, t, tau),
                                    deliver))
        return new_params, new_opt, metrics, new_state

    return step
