"""Byzantine-resilient ensemble serving: robust aggregation at decode time.

The paper's core claim — a single Byzantine participant exploits the
:math:`\\Omega(\\sqrt{d})` leeway of convergent aggregation rules — applies
to inference-time ensembles exactly as it does to training: ``n`` replica
parameter sets (independent fine-tunes, quantized variants, or mirrored
servers, some of which may be compromised) each produce per-token logits,
and a master that *averages* them hands one poisoned replica control over
every greedy decode.  This module is the serving-side analogue of
``repro.dist.robust``:

* replicas are a **stacked parameter pytree** — every leaf carries a
  leading ``(n_replicas,)`` axis (``stack_replicas`` /
  ``replicate_params``), which the mesh layer maps onto the ``data`` axis
  (``repro.dist.sharding.ensemble_param_shardings``) so each replica's
  forward runs data-parallel while its weights stay ``model``-sharded;
* poisoning reuses the training-side machinery verbatim:
  ``poison_replicas`` rewrites the last ``f`` replicas' *parameters*
  through ``repro.dist.robust.inject_byzantine``, and a decode-time
  in-graph attack on the stacked *logits* (``spec.attack``) mirrors
  ``make_train_step``'s omniscient adversary;
* aggregation is the unchanged ``repro.agg`` registry applied to the
  ``(n, B, V)`` logits stack per decode step — Krum selects one replica's
  distribution, Bulyan trims per vocabulary entry, and the stateful rules
  (``buffered-*``, ``centered_clip_momentum``) thread an ``AggState``
  **across tokens**, filtering slow-drift poisoning over the decode
  stream.  Distances run through the same leaf-wise Gram machinery and
  ``distance_backend=`` xla/pallas dispatch as training.

No rule is forked for serving: ``aggregate_logits`` wraps the stack in a
single-leaf tree and calls ``distributed_aggregate``, so every registry
rule with a tree implementation works unchanged as a serving aggregator
(pinned by ``tests/test_serve_robust.py``).

The continuous-batching driver lives in ``repro.serving.engine``
(``ServingEngine(..., ensemble=spec)``); see ``docs/serving.md`` for the
architecture, including the AggState-across-tokens contract.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.agg.specs import AggSpec
from repro.agg.state import AggState, init_state
from repro.dist.robust import distributed_aggregate, inject_byzantine
from repro.models import decode_step, prefill, verify_step
from repro.models.config import ModelConfig
from repro.obs.trace import named_span

__all__ = ["aggregate_logits", "init_ensemble_state",
           "make_robust_prefill_step", "make_robust_serve_step",
           "make_robust_verify_step", "poison_replicas", "replicate_cache",
           "replicate_params", "reset_slot_state", "stack_replicas"]


# ---------------------------------------------------------------------------
# replica parameter stacks
# ---------------------------------------------------------------------------

def stack_replicas(replicas: Sequence[Any]) -> Any:
    """Stack per-replica parameter pytrees along a new leading axis.

    Args:
      replicas: sequence of structurally identical parameter pytrees
        (one per ensemble member).

    Returns:
      One pytree whose every leaf is the ``(n_replicas, *dims)`` stack of
      the corresponding per-replica leaves — the layout every function in
      this module (and ``ServingEngine``'s ensemble mode) consumes.
    """
    if not replicas:
        raise ValueError("need at least one replica")
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves, axis=0), *replicas)


def replicate_params(params: Any, n_replicas: int, *, jitter: float = 0.0,
                     key: Optional[jax.Array] = None) -> Any:
    """Broadcast one parameter set into an ``n_replicas``-stacked ensemble.

    Args:
      params: parameter pytree of a single model.
      n_replicas: ensemble size (the leading axis of every output leaf).
      jitter: per-replica Gaussian perturbation scale, relative to each
        leaf's RMS value (``0.0`` = exact copies).  A small jitter models
        independently fine-tuned replicas and gives distance-based rules
        an honest cluster to select from.
      key: PRNG key for the jitter (``None`` = ``PRNGKey(0)``); ignored
        when ``jitter == 0``.

    Returns:
      A pytree whose leaves are ``(n_replicas, *dims)`` stacks of the
      input leaves, optionally jittered per replica.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n_replicas,) + p.shape), params)
    if jitter <= 0.0:
        return stacked
    if key is None:
        key = jax.random.PRNGKey(0)
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    out = []
    for j, leaf in enumerate(leaves):
        rms = jnp.sqrt(jnp.mean(jnp.square(leaf.astype(jnp.float32))) + 1e-12)
        noise = jitter * rms * jax.random.normal(
            jax.random.fold_in(key, j), leaf.shape, jnp.float32)
        out.append((leaf.astype(jnp.float32) + noise).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicate_cache(cache: Any, n_replicas: int) -> Any:
    """Grow a decode cache a leading replica axis (zero-state broadcast).

    Every replica starts from the same (empty) cache, so a plain
    broadcast is exact; from the first decode step on, each replica's
    cache diverges with its parameters.  This is the one place the
    ensemble cache layout (leading ``(n_replicas,)`` axis on every
    ``periods``/``tail`` leaf) is defined — the engine, tests, and
    benchmarks all build their stacked caches here.

    Args:
      cache: decode-cache pytree from ``repro.models.init_cache``.
      n_replicas: ensemble size.

    Returns:
      The cache pytree with every leaf broadcast to
      ``(n_replicas, *leaf.shape)``.
    """
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_replicas,) + x.shape), cache)


def poison_replicas(stacked_params: Any, f: int, attack: str = "signflip",
                    key: Optional[jax.Array] = None, **attack_kwargs) -> Any:
    """Rewrite the last ``f`` replicas' parameters with a Byzantine attack.

    This is the training-side ``repro.dist.robust.inject_byzantine``
    applied to *parameters* instead of gradients: the adversary reads the
    ``n - f`` honest replicas' weights and overwrites the last ``f``
    rows of every leaf (e.g. ``"signflip"`` with a large scale produces a
    replica whose logits are confidently wrong — the serving analogue of
    the paper's poisoned worker).

    Args:
      stacked_params: ``(n_replicas, *dims)``-stacked parameter pytree.
      f: number of replicas to poison (the trailing rows; ``f <= 0`` is a
        no-op).
      attack: any attack name ``inject_byzantine`` accepts (signflip,
        zero, mimic, ipm, random, alie, omniscient_linf, omniscient_lp).
      key: PRNG key for stochastic attacks.
      **attack_kwargs: per-attack parameters forwarded verbatim (scale,
        eps, z, gamma, ...).

    Returns:
      The stacked pytree with the last ``f`` replicas replaced; shapes
      and dtypes preserved exactly.
    """
    return inject_byzantine(stacked_params, f, attack, key=key,
                            **attack_kwargs)


# ---------------------------------------------------------------------------
# logits aggregation (the one entry point every serving path shares)
# ---------------------------------------------------------------------------

def aggregate_logits(logits: jnp.ndarray, f: int, gar: str, *,
                     agg_dtype: str = "native",
                     distance_backend: str = "auto", mesh=None,
                     state: Optional[AggState] = None,
                     history_window: Optional[int] = None,
                     rep_lr: Optional[float] = None,
                     rep_decay: Optional[float] = None):
    """Aggregate a replica-stacked logits tensor through the GAR registry.

    The stack is wrapped in a single-leaf tree and handed to
    ``repro.dist.robust.distributed_aggregate``, so the coordinate space
    is the flattened ``batch x vocab`` plane and the semantics contract
    is the flat core rule on ``logits.reshape(n, -1)`` — no
    serving-specific rule forks exist (see ``tests/test_serve_robust.py``
    for the parity pin).

    Args:
      logits: ``(n_replicas, batch, vocab)`` (or ``(n_replicas, vocab)``)
        replica-stacked logits of one decode step.
      f: Byzantine bound the rule defends against (quorum-checked).
      gar: any name ``repro.agg.resolve_rule`` accepts with a tree
        implementation (``krum``, ``bulyan-<base>``, ``buffered-<base>``,
        ``centered_clip_momentum``, ...).
      agg_dtype: accumulation dtype contract (see ``repro.dist.robust``).
      distance_backend: ``"xla"`` | ``"pallas"`` | ``"auto"`` for the
        ``(n, n)`` replica-distance matrix of distance-based rules.
      mesh: optional device mesh for the shard-mapped Pallas path.
      state: carried ``AggState`` for stateful rules (``None``
        zero-initializes one in-graph); stateless rules ignore it.
      history_window: ``buffered-*`` window length (``None`` = default).
      rep_lr: ``reputation-*`` EMA rate (``None`` = registry default;
        ignored by other rules — see ``repro.agg.reputation``).
      rep_decay: ``reputation-*`` forgetting factor (same default rule).

    Returns:
      ``(aggregated logits, DistAggResult)`` for stateless rules and
      ``(aggregated logits, DistAggResult, new_state)`` for stateful
      ones — the aggregated array drops the replica axis and keeps the
      input dtype.
    """
    out = distributed_aggregate(
        {"logits": logits}, f, gar, agg_dtype=agg_dtype,
        distance_backend=distance_backend, mesh=mesh, state=state,
        history_window=history_window, rep_lr=rep_lr, rep_decay=rep_decay)
    agg = out[0]["logits"]
    if len(out) == 3:
        return agg, out[1], out[2]
    return agg, out[1]


def init_ensemble_state(spec: AggSpec, n_replicas: int, batch: int,
                        vocab: int) -> Optional[AggState]:
    """Zeroed ``AggState`` for a stateful serving aggregator.

    The state template is the ``(n_replicas, batch, vocab)`` logits stack
    the decode step aggregates, so window buffers come out as
    ``(W, n_replicas, batch, vocab)`` — one history of the full slot
    batch, carried across tokens.  ``reputation-*`` rules get a
    **per-slot** ``(n_replicas, batch)`` trust layout (``rep_dims``), so
    each request's decode stream earns its own replica scores and slot
    reuse can reset one column (:func:`reset_slot_state`) without
    touching concurrent requests.  Composes with ``jax.eval_shape``
    (only shapes are read).

    Args:
      spec: the serving ``AggSpec`` (``gar`` / ``history_window`` select
        the rule and its window).
      n_replicas: ensemble size.
      batch: decode batch (the engine's slot count).
      vocab: vocabulary size.

    Returns:
      An ``AggState`` sized for the logits stack, or ``None`` when the
      rule is stateless.
    """
    rule = spec.rule()
    if not rule.stateful:
        return None
    template = {"logits": jax.ShapeDtypeStruct(
        (n_replicas, batch, vocab), jnp.float32)}
    return init_state(rule, template, flat=False, rep_dims=(batch,))


def reset_slot_state(state: Optional[AggState],
                     slot: int) -> Optional[AggState]:
    """Clear one batch slot's column of a serving ``AggState``.

    The serving engine's stateful-rule state is laid out per the
    :func:`init_ensemble_state` template — ``history`` leaves are
    ``(W, n_replicas, batch, vocab)`` and ``center`` leaves
    ``(batch, vocab)`` — so a request admitted into a *reused* slot
    would otherwise inherit the sliding-window / momentum history of the
    slot's previous occupant and decode a polluted stream.  The engine
    calls this at admission to zero exactly the admitted slot's column;
    other slots' carried state (and the global ``step`` counter) are
    untouched.

    Args:
      state: the engine's carried ``AggState`` (``None`` for stateless
        rules — returned unchanged).
      slot: batch-slot index being (re)admitted.

    Returns:
      The state with ``history[:, :, slot]`` / ``center[slot]`` zeroed
      and the slot's ``reputation[:, slot]`` column restored to **ones**
      (the neutral full-trust init — a new request must not inherit, nor
      be punished by, the previous occupant's replica scores), or
      ``None`` when ``state`` is ``None``.
    """
    if state is None:
        return None
    history = tuple(h.at[:, :, slot].set(0.0) for h in state.history) \
        if state.history != () else ()
    center = tuple(c.at[slot].set(0.0) for c in state.center) \
        if state.center != () else ()
    reputation = state.reputation
    if not isinstance(reputation, tuple) and reputation.ndim == 2:
        reputation = reputation.at[:, slot].set(1.0)
    return state._replace(history=history, center=center,
                          reputation=reputation)


# ---------------------------------------------------------------------------
# jit-able ensemble steps
# ---------------------------------------------------------------------------

def _maybe_attack_logits(stack: jnp.ndarray, spec: AggSpec, pos) -> jnp.ndarray:
    """Decode-time omniscient adversary on the stacked logits (in-graph)."""
    if spec.attack == "none" or spec.f <= 0:
        return stack
    # fold in the *sum* of positions: under continuous batching any active
    # slot advancing refreshes the key (pos[0] alone freezes once slot 0
    # finishes, replaying identical noise for stochastic attacks)
    key = jax.random.fold_in(
        jax.random.PRNGKey(spec.seed),
        jnp.sum(jnp.asarray(pos, jnp.int32)))
    akw = dict(spec.attack_kwargs)
    akw.setdefault("gar_name", spec.gar)
    return inject_byzantine({"logits": stack}, spec.f, spec.attack,
                            key=key, **akw)["logits"]


def make_robust_prefill_step(cfg: ModelConfig, spec: AggSpec,
                             cache_len: int = 0, impl: str = "auto",
                             mesh=None) -> Callable:
    """Build the ensemble prefill: per-replica forward + robust first token.

    The returned ``prefill_step(stacked_params, tokens[, extra]) ->
    (agg_logits, stacked_cache, diag)`` vmaps the model's prefill over
    the replica axis (every replica sees the same prompt), then
    aggregates the **last-position** logits ``(n, B, vocab)`` through
    ``spec.gar`` so the first sampled token is already Byzantine-filtered.
    Caches come back replica-stacked, ready for
    ``make_robust_serve_step``.  Stateful rules aggregate the prefill
    decision from a fresh zero state (the carried-state contract starts
    on the decode stream — see docs/serving.md).

    Args:
      cfg: model configuration of every replica.
      spec: serving ``AggSpec`` (``gar``, declared ``f``, ``agg_dtype``,
        ``distance_backend``, ``history_window``).
      cache_len: decode-cache length to allocate (``0`` = prompt length).
      impl: attention implementation forwarded to prefill.
      mesh: optional device mesh for the Pallas distance path.

    Returns:
      The jit-able ``prefill_step`` closure described above; ``diag`` is
      the ``DistAggResult`` of the aggregation (per-replica weights and
      scores).
    """

    def prefill_step(stacked_params, tokens: jnp.ndarray,
                     extra: Optional[jnp.ndarray] = None):
        logits, caches = jax.vmap(
            lambda p: prefill(p, cfg, tokens, extra, cache_len=cache_len,
                              impl=impl))(stacked_params)
        stack = logits[:, :, -1, :].astype(jnp.float32)
        out = aggregate_logits(
            stack, spec.f_declared, spec.effective_gar,
            agg_dtype=spec.agg_dtype,
            distance_backend=spec.distance_backend, mesh=mesh,
            history_window=spec.history_window,
            rep_lr=spec.rep_lr, rep_decay=spec.rep_decay)
        return out[0], caches, out[1]

    return prefill_step


def make_robust_serve_step(cfg: ModelConfig, spec: AggSpec,
                           mesh=None) -> Callable:
    """Build the jit-able robust ensemble decode step.

    The returned ``serve_step(stacked_params, stacked_cache, token, pos,
    agg_state) -> (agg_logits, new_cache, diag, new_agg_state)`` decodes
    one token on every replica (vmap over the leading replica axis of
    params and cache — the same ``token``/``pos`` feed every replica),
    optionally applies ``spec.attack`` to the stacked logits in-graph
    (the omniscient decode-time adversary, mirroring the train step),
    and aggregates the ``(n, B, vocab)`` stack through ``spec.gar``.

    ``pos`` follows the ``make_serve_step`` contract: a scalar or a
    ``(B,)`` int32 per-slot position vector (continuous batching).
    ``agg_state`` is the carried ``AggState`` for stateful rules —
    thread the returned state into the next call so ``buffered-*`` /
    ``centered_clip_momentum`` filter across the decode stream; pass
    (and receive) ``None`` for stateless rules, whose signature cost is
    zero.

    Args:
      cfg: model configuration of every replica.
      spec: serving ``AggSpec``; ``spec.attack`` ("none" to disable)
        poisons the last ``spec.f`` replicas' logits in-graph.
      mesh: optional device mesh for the Pallas distance path.

    Returns:
      The ``serve_step`` closure described above; ``agg_logits`` is
      ``(B, vocab)`` with the replica axis aggregated away and ``diag``
      the per-replica ``DistAggResult``.
    """
    stateful = spec.rule().stateful

    def serve_step(stacked_params, stacked_cache, token: jnp.ndarray, pos,
                   agg_state: Optional[AggState] = None):
        logits, new_cache = jax.vmap(
            lambda p, c: decode_step(p, cfg, c, token, pos)
        )(stacked_params, stacked_cache)
        stack = logits[:, :, 0, :].astype(jnp.float32)
        stack = _maybe_attack_logits(stack, spec, pos)
        out = aggregate_logits(
            stack, spec.f_declared, spec.effective_gar,
            agg_dtype=spec.agg_dtype,
            distance_backend=spec.distance_backend, mesh=mesh,
            state=agg_state, history_window=spec.history_window,
            rep_lr=spec.rep_lr, rep_decay=spec.rep_decay)
        new_state = out[2] if stateful else None
        return out[0], new_cache, out[1], new_state

    return serve_step


def make_robust_verify_step(cfg: ModelConfig, spec: AggSpec,
                            mesh=None) -> Callable:
    """Build the jit-able batched speculative-verify step.

    The returned ``verify(stacked_params, stacked_cache, tokens, pos,
    agg_state) -> (agg_logits, new_cache, diag, new_agg_state)`` runs the
    ensemble over a whole ``(B, k)`` draft block in **one** model pass
    per replica (``repro.models.verify_step`` — keys written first,
    per-query causal masking), optionally applies ``spec.attack`` to the
    stacked logits in-graph, and aggregates the resulting
    ``(n, B, k, vocab)`` stack through the unchanged ``repro.agg``
    registry.

    Aggregation is **per position, in stream order**: a ``lax.scan``
    over the block's ``k`` positions applies ``aggregate_logits`` to
    each ``(n, B, vocab)`` slice, threading the carried ``AggState``
    from position to position — so every registered tree rule keeps the
    exact per-token semantics (and state evolution) of the PR-4 decode
    path, and a ``k = 1`` block *is* that path.  The whole scan lives in
    a single jit'd computation, so the per-token dispatch cost of the
    per-token path is paid once per block.

    Args:
      cfg: model configuration of every replica (must satisfy
        ``repro.models.verify_supported`` — ring/SSM caches cannot roll
        back rejected draft tokens).
      spec: serving ``AggSpec`` (``gar``, declared ``f``, ``agg_dtype``,
        ``distance_backend``, ``history_window``; ``spec.attack``
        poisons the last ``spec.f`` replicas' logits in-graph, at every
        block position).
      mesh: optional device mesh for the Pallas distance path.

    Returns:
      The ``verify`` closure described above.  ``agg_logits`` is
      ``(B, k, vocab)`` with the replica axis aggregated away; ``diag``
      is a per-position ``DistAggResult`` (leaves lead with a ``(k,)``
      axis).
    """
    from repro.models import verify_supported
    ok, reason = verify_supported(cfg)
    if not ok:
        raise ValueError(
            f"speculative verify unsupported for {cfg.name!r} — {reason}")
    stateful = spec.rule().stateful

    def _agg_one(state, slice_nbv):
        with named_span("serve/verify"):
            out = aggregate_logits(
                slice_nbv, spec.f_declared, spec.effective_gar,
                agg_dtype=spec.agg_dtype,
                distance_backend=spec.distance_backend, mesh=mesh,
                state=state if stateful else None,
                history_window=spec.history_window,
                rep_lr=spec.rep_lr, rep_decay=spec.rep_decay)
        new_state = out[2] if stateful else state
        return new_state, (out[0], out[1])

    def verify(stacked_params, stacked_cache, tokens: jnp.ndarray, pos,
               agg_state: Optional[AggState] = None):
        logits, new_cache = jax.vmap(
            lambda p, c: verify_step(p, cfg, c, tokens, pos)
        )(stacked_params, stacked_cache)
        stack = logits.astype(jnp.float32)        # (n, B, k, V)
        stack = _maybe_attack_logits(stack, spec, pos)
        xs = jnp.moveaxis(stack, 2, 0)            # (k, n, B, V) stream order
        agg_state, (aggs, diag) = jax.lax.scan(_agg_one, agg_state, xs)
        agg_logits = jnp.moveaxis(aggs, 0, 1)     # (B, k, V)
        return agg_logits, new_cache, diag, (agg_state if stateful
                                             else None)

    return verify
