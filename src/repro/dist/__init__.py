"""Mesh-sharded distributed runtime.

The core GARs (``repro.core``) operate on a flat ``(n, d)`` matrix — fine
for one device, fatal at scale: materializing every worker's full gradient
vector in one array defeats model-parallel sharding.  This package is the
production path:

  mesh.py      device meshes (host smoke meshes + the production pods)
  sharding.py  NamedSharding/PartitionSpec rules for params, optimizer
               state, worker-stacked batches, and KV caches
  robust.py    the tree-aware aggregation *engine*: per-leaf partial
               Gram matrices (the (n, n) distance matrix is the only
               global object), the distance_backend= xla/pallas/auto
               dispatch (shard-mapped Pallas kernel on the sharded
               path), windowed coordinate phase, per-leaf attacks —
               rule bodies resolve through the ``repro.agg`` registry
  train.py     the jit-able sharded Byzantine train step
  async_train.py  the asynchronous bounded-staleness runtime: the
               versioned GradientBus, deterministic delay schedules,
               and the async train step aggregating the slot stack
               through the same registry (docs/async-runtime.md)
  serve.py     prefill/decode steps consumed by the dry-run and engine
  serve_robust.py  Byzantine-resilient ensemble serving: replica param
               stacks (axis mapped onto ``data``), per-token logits
               aggregation through the ``repro.agg`` registry, AggState
               carried across the decode stream (docs/serving.md)

Everything is plain jit-compatible jnp: sharding enters exclusively via
the input/output shardings (XLA GSPMD propagation), so the same step
function runs unsharded on one device and sharded on a pod — which is
exactly what ``tests/test_dist.py`` pins down.  The one deliberate
exception is the Pallas distance backend, whose ``shard_map`` block pins
the kernel's layout explicitly; see docs/dist-runtime.md.
"""
from repro.dist.mesh import (make_host_mesh, make_production_mesh,
                             mesh_axis_sizes)
from repro.dist.robust import (DistAggResult, coordinate_phase_nd,
                               distributed_aggregate, inject_byzantine,
                               pairwise_sq_dists_tree,
                               resolve_distance_backend)
from repro.dist.sharding import (batch_pspec, cache_shardings,
                                 ensemble_cache_shardings,
                                 ensemble_param_shardings, gram_pspec,
                                 param_shardings)
from repro.dist.train import (DistByzantineSpec, init_agg_state,
                              make_loss_fn, make_train_step)
from repro.dist.async_train import (GradientBus, delivery_mask,
                                    init_async_state, init_bus,
                                    make_async_train_step, resolve_tau,
                                    update_bus)
from repro.dist.serve import make_prefill_step, make_serve_step
from repro.dist.serve_robust import (aggregate_logits, init_ensemble_state,
                                     make_robust_prefill_step,
                                     make_robust_serve_step,
                                     poison_replicas, replicate_cache,
                                     replicate_params, stack_replicas)

__all__ = [
    "DistAggResult", "DistByzantineSpec", "GradientBus", "aggregate_logits",
    "batch_pspec", "cache_shardings", "coordinate_phase_nd",
    "delivery_mask", "distributed_aggregate", "ensemble_cache_shardings",
    "ensemble_param_shardings", "gram_pspec", "init_agg_state",
    "init_async_state", "init_bus", "init_ensemble_state",
    "inject_byzantine", "make_async_train_step", "make_host_mesh",
    "make_loss_fn", "make_prefill_step", "make_production_mesh",
    "make_robust_prefill_step", "make_robust_serve_step", "make_serve_step",
    "make_train_step", "mesh_axis_sizes", "pairwise_sq_dists_tree",
    "param_shardings", "poison_replicas", "replicate_cache",
    "replicate_params", "resolve_distance_backend", "resolve_tau",
    "stack_replicas", "update_bus",
]
