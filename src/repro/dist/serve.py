"""Serving steps for the dry-run and the serving engine.

Thin, jit-able closures over the model's prefill/decode paths — the
sharded layout comes from ``repro.dist.sharding`` (params over ``model``,
batch and KV caches over the data-parallel axes), applied by the caller
via input/output shardings exactly as in ``repro.launch.dryrun``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig

__all__ = ["make_prefill_step", "make_serve_step"]


def make_prefill_step(cfg: ModelConfig, impl: str = "auto") -> Callable:
    """``step(params, tokens[, extra]) -> (logits, cache)`` — full-sequence
    forward that also populates decode caches (cache_len = seq_len)."""

    def prefill_step(params, tokens: jnp.ndarray,
                     extra: Optional[jnp.ndarray] = None):
        return prefill(params, cfg, tokens, extra=extra, impl=impl)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """``step(params, cache, token, pos) -> (logits, new_cache)`` — one
    decode token for every sequence in the batch; ``pos`` is a scalar or
    (B,) per-slot position vector (continuous batching).  Single-token
    decode has no attention-impl choice, hence no ``impl`` knob."""

    def serve_step(params, cache, token: jnp.ndarray, pos):
        return decode_step(params, cfg, cache, token, pos)

    return serve_step
