"""Serving steps for the dry-run and the serving engine.

Thin, jit-able closures over the model's prefill/decode paths — the
sharded layout comes from ``repro.dist.sharding`` (params over ``model``,
batch and KV caches over the data-parallel axes), applied by the caller
via input/output shardings exactly as in ``repro.launch.dryrun``.

The Byzantine-resilient *ensemble* analogues of these steps live in
``repro.dist.serve_robust`` (``make_robust_prefill_step`` /
``make_robust_serve_step``): there the leading replica axis of the
stacked parameters and caches maps onto the ``data`` mesh axis
(``repro.dist.sharding.ensemble_param_shardings`` /
``ensemble_cache_shardings``) and the per-token logits stack is
aggregated through the ``repro.agg`` registry.  See docs/serving.md.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.models import decode_step, prefill
from repro.models.config import ModelConfig

__all__ = ["make_prefill_step", "make_serve_step"]


def make_prefill_step(cfg: ModelConfig, impl: str = "auto") -> Callable:
    """Build the full-sequence prefill step.

    Args:
      cfg: model configuration.
      impl: attention implementation (``"auto"`` | ``"naive"`` |
        ``"blockwise"``), forwarded to the model's prefill.

    Returns:
      ``prefill_step(params, tokens[, extra]) -> (logits, cache)`` — a
      full-sequence forward that also populates decode caches
      (``cache_len`` = sequence length).
    """

    def prefill_step(params, tokens: jnp.ndarray,
                     extra: Optional[jnp.ndarray] = None):
        return prefill(params, cfg, tokens, extra=extra, impl=impl)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """Build the single-token batched decode step.

    Args:
      cfg: model configuration.

    Returns:
      ``serve_step(params, cache, token, pos) -> (logits, new_cache)`` —
      one decode token for every sequence in the batch.

      The ``pos`` contract: either a scalar ``()`` (every sequence at
      the same position — the dry-run decode shape) or a ``(B,)`` int32
      per-slot position vector (continuous batching — each sequence
      ropes and cache-writes at its own index; this is what
      ``ServingEngine`` passes).  Host callers should keep their
      counters int32 to match — the engine's ``positions`` array is
      ``np.int32`` precisely so no int64 promotion crosses the
      host/device boundary.  Single-token decode has no attention-impl
      choice, hence no ``impl`` knob.
    """

    def serve_step(params, cache, token: jnp.ndarray, pos):
        return decode_step(params, cfg, cache, token, pos)

    return serve_step
