"""Tree-aware robust aggregation — the sharded path of every GAR.

The core rules (``repro.core.gars``) consume a flat ``(n, d)`` matrix.
Building that matrix at production scale means concatenating every
parameter shard of every worker into one array — an all-gather of the
full model per step.  This module keeps gradients as pytrees whose leaves
carry a leading worker axis and exploits the structure of the rules:

  * Distance-based selection (Krum, GeoMed, Bulyan phase 1) only needs
    the (n, n) squared-distance matrix.  We accumulate it as a sum of
    per-leaf partial Gram matrices (one tensordot per leaf over all
    trailing dims) — under GSPMD each tensordot contracts the
    model-sharded dims locally and the (n, n) result is all-reduced,
    so the only globally materialized object is n x n.
  * Coordinate-wise phases (cwmed, trimmed mean, Bulyan phase 2) are
    embarrassingly parallel over coordinates and run per-leaf, preserving
    each leaf's sharding.  ``coordinate_phase_nd`` additionally supports
    windowing over the flattened trailing dims to bound the O(theta * d)
    sort workspace.

This module is the *engine* only: the rule bodies themselves live behind
the unified registry (``repro.agg`` — tree implementations in
``repro.agg.tree`` / ``repro.agg.buffered``), and
``distributed_aggregate`` hands them the machinery below through a
``TreeContext``.

Accumulation dtype: the flat reference casts everything to fp32
(``repro.core.pytree.stack_flatten``), so the default here is fp32 too —
bf16 gradients are aggregated in fp32 and cast back.  ``agg_dtype=
"bfloat16"`` is the perf experiment knob (halves distance-pass traffic
on the XLA backend; the Pallas kernel streams the input dtype from HBM
but always *accumulates* fp32 on-chip, so there the knob only thins the
HBM stream and the two backends can differ at bf16 beyond the fp32
parity bound).

Distance backend: the (n, n) matrix is the hot path of every
distance-based GAR, and it has two interchangeable implementations behind
``distance_backend=``:

  "xla"     per-leaf ``jnp.tensordot`` partial Grams (GSPMD shards the
            contraction implicitly) — works everywhere, the semantics
            reference;
  "pallas"  the VMEM-tiled MXU kernel ``repro.kernels.pairwise_gram``.
            With a ``mesh``, each model shard runs the kernel on its local
            d-slice under ``shard_map`` and only the (n, n) partials are
            psum'd — same "no flat (n, d) matrix" invariant, explicit
            tiling.  Falls back to the Pallas interpreter off-TPU so CPU
            CI exercises the identical code path;
  "auto"    "pallas" on TPU when a mesh with a non-trivial model axis is
            threaded through; "xla" everywhere else (see
            ``resolve_distance_backend`` for why the mesh is required);
  "fused"   the single-sweep megakernel ``repro.kernels.fused_agg``:
            ``distributed_aggregate`` reroutes the rule itself onto its
            ``fused-<base>`` registry composite (``repro.agg.fused``),
            so distance accumulation, selection and the coordinate phase
            run in one ``pallas_call`` — no distance matrix round-trips
            HBM on the flat/single-leaf path at all.  With a mesh whose
            ``model`` axis is non-trivial the knob degrades to "pallas"
            (the megakernel has no shard_map partitioning; the
            shard-mapped pair path keeps the semantics).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.pairwise_gram import (finalize_dists,
                                         pairwise_gram_partial,
                                         pairwise_gram_tree)
from repro.obs.trace import named_span

__all__ = ["DistAggResult", "coordinate_phase_nd", "distributed_aggregate",
           "inject_byzantine", "pairwise_sq_dists_tree",
           "resolve_distance_backend"]


class DistAggResult(NamedTuple):
    """Per-worker diagnostics of one distributed aggregation (the
    aggregate itself is returned as a pytree alongside)."""

    selected: jnp.ndarray  # (n,) weights of each worker in the output
    scores: jnp.ndarray    # (n,) rule scores (lower = better), or zeros


def _leaves(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("empty gradient tree")
    return leaves


def _worker_count(tree) -> int:
    leaves = _leaves(tree)
    n = leaves[0].shape[0]
    for leaf in leaves:
        if leaf.ndim < 1 or leaf.shape[0] != n:
            raise ValueError(
                f"every leaf needs a leading worker axis of {n}, got "
                f"shape {leaf.shape}")
    return n


def _compute_dtype(agg_dtype: str):
    if agg_dtype == "bfloat16":
        return jnp.bfloat16
    if agg_dtype in ("native", "float32"):
        return jnp.float32
    raise ValueError(f"unknown agg_dtype {agg_dtype!r}")


def _trailing_axes(leaf) -> Tuple[int, ...]:
    return tuple(range(1, leaf.ndim))


# ---------------------------------------------------------------------------
# distances
# ---------------------------------------------------------------------------

def resolve_distance_backend(distance_backend: str, mesh=None) -> str:
    """Resolve the user-facing backend knob to a concrete implementation.

    Args:
      distance_backend: ``"xla"`` | ``"pallas"`` | ``"fused"`` |
        ``"auto"``.
      mesh: the mesh that would drive the shard-mapped Pallas pass
        (``None`` when the caller did not thread one through).

    Returns:
      ``"xla"``, ``"pallas"`` or ``"fused"``.  ``"auto"`` picks the
      Pallas kernel only on TPU *and* with a mesh whose ``model`` axis
      is non-trivial: without the mesh the kernel would run as a plain
      ``pallas_call`` inside the GSPMD program, and XLA has no
      partitioning rule for it — it would all-gather every
      model-sharded gradient leaf, exactly the flat materialization this
      module forbids.  Off-TPU the clean fallback is XLA (interpret mode
      is pure-Python per grid step).  An explicit ``"pallas"`` is
      honored as given — opting in without a mesh is the
      single-device/debug path.  ``"fused"`` degrades to ``"pallas"``
      under a non-trivial ``model`` axis for the same partitioning
      reason: the megakernel holds the whole d-tile sweep in one kernel,
      so the shard-mapped pair path takes over on sharded meshes.
    """
    if distance_backend == "auto":
        if jax.default_backend() != "tpu":
            return "xla"
        from repro.dist.mesh import mesh_axis_sizes
        has_model = (mesh is not None
                     and mesh_axis_sizes(mesh).get("model", 1) > 1)
        return "pallas" if has_model else "xla"
    if distance_backend == "fused":
        from repro.dist.mesh import mesh_axis_sizes
        has_model = (mesh is not None
                     and mesh_axis_sizes(mesh).get("model", 1) > 1)
        return "pallas" if has_model else "fused"
    if distance_backend not in ("xla", "pallas"):
        raise ValueError(
            f"distance_backend must be 'xla', 'pallas', 'fused' or "
            f"'auto', got {distance_backend!r}")
    return distance_backend


def _pallas_sharded_dists(tree: Any, mesh, *, block_d: int,
                          interpret: Optional[bool]) -> jnp.ndarray:
    """Shard-mapped Pallas distance pass: each model shard runs the tiled
    kernel on its local d-slice of every leaf, then the (n, n) raw
    partials are psum'd over ``model``.  Worker rows are replicated into
    each shard (an (n, d/model) gather — the same traffic GSPMD's
    implicit sharding of the tensordot path pays), so shards differing
    only in their data/pod coordinate compute identical results and the
    output is replicated.

    Leaves too small/ragged to divide by the model axis enter fully
    replicated (``gram_pspec`` gives them ``P()``): every shard computes
    their whole partial, so those partials must stay *out* of the psum —
    summing them post-reduction instead of multiplying them by the axis
    size."""
    from jax.experimental.shard_map import shard_map

    from repro.dist.sharding import gram_pspec

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    if not flat:
        raise ValueError("empty gradient tree")
    leaves = [leaf for _, leaf in flat]
    in_specs = tuple(gram_pspec(leaf.shape, mesh, path)
                     for path, leaf in flat)
    is_sharded = tuple("model" in spec for spec in in_specs)

    def local_partials(*local_leaves):
        n = local_leaves[0].shape[0]
        sharded = jnp.zeros((n, n), jnp.float32)
        replicated = jnp.zeros((n, n), jnp.float32)
        for leaf, shd in zip(local_leaves, is_sharded):
            part = pairwise_gram_partial(
                leaf, block_d=block_d, interpret=interpret)
            if shd:
                sharded = sharded + part
            else:
                replicated = replicated + part
        if "model" in mesh.axis_names:
            sharded = jax.lax.psum(sharded, "model")
        return sharded + replicated

    mapped = shard_map(local_partials, mesh=mesh, in_specs=in_specs,
                       out_specs=P(), check_rep=False)
    return finalize_dists(mapped(*leaves))


def pairwise_sq_dists_tree(tree: Any, compute_dtype=jnp.float32, *,
                           distance_backend: str = "xla", mesh=None,
                           block_d: int = 4096,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Squared euclidean distances over the *concatenation* of all leaves.

    Args:
      tree: pytree of ``(n, *dims)`` worker-stacked gradients (ragged
        trailing dims allowed; every leaf shares the worker axis).
      compute_dtype: accumulation dtype of the ``"xla"`` backend and the
        dtype of the returned matrix (the Pallas kernel always
        accumulates fp32 internally).
      distance_backend: ``"xla"`` | ``"pallas"`` | ``"fused"`` |
        ``"auto"`` — see ``resolve_distance_backend`` (``"fused"`` uses
        the same tiled Pallas accumulation here).
      mesh: optional device mesh.  With the Pallas backend and a mesh
        whose ``model`` axis is non-trivial, the kernel runs per model
        shard under ``shard_map`` and the (n, n) partials are psum'd;
        otherwise the kernel runs on whole (unsharded) leaves.
      block_d: Pallas VMEM tile width (ignored by the XLA backend).
      interpret: Pallas interpret override (``None`` = auto per backend).

    Returns:
      ``(n, n)`` squared distances in ``compute_dtype``, computed as a
      sum of per-leaf partial Gram matrices — no flat (n, d) copy is
      ever built on either backend.
    """
    n = _worker_count(tree)
    backend = resolve_distance_backend(distance_backend, mesh)
    # the "fused" knob reroutes the *rule* (see distributed_aggregate);
    # its distance matrix, when a rule still asks for one, is the same
    # tiled Pallas accumulation
    with named_span("agg/gram"):
        if backend in ("pallas", "fused"):
            from repro.dist.mesh import mesh_axis_sizes
            if mesh is not None and mesh_axis_sizes(mesh).get("model",
                                                              1) > 1:
                d2 = _pallas_sharded_dists(tree, mesh, block_d=block_d,
                                           interpret=interpret)
            else:
                d2 = pairwise_gram_tree(tree, block_d=block_d,
                                        interpret=interpret)
            return d2.astype(compute_dtype)
        gram = jnp.zeros((n, n), compute_dtype)
        sq = jnp.zeros((n,), compute_dtype)
        for leaf in _leaves(tree):
            x = leaf.astype(compute_dtype)
            axes = _trailing_axes(leaf)
            gram = gram + jnp.tensordot(x, x, axes=(axes, axes))
            sq = sq + jnp.sum(x * x, axis=axes)
        return finalize_dists(sq[:, None] + sq[None, :] - 2.0 * gram)


# ---------------------------------------------------------------------------
# coordinate phase over arbitrary trailing dims
# ---------------------------------------------------------------------------

def _phase_nd(selected: jnp.ndarray, f: int) -> jnp.ndarray:
    """Bulyan phase 2 on a (theta, ...) stack, axis-0 vectorized over all
    trailing dims.  Identical windowed algorithm to
    ``repro.core.bulyan.coordinate_phase`` (see there for the contiguous-
    window argument)."""
    theta = selected.shape[0]
    beta = theta - 2 * f
    s = jnp.sort(selected, axis=0)
    if beta == theta:
        return jnp.mean(s, axis=0)
    med = s[(theta - 1) // 2]
    absdev = jnp.abs(s - med[None])
    zeros = jnp.zeros_like(s[:1])
    cd = jnp.concatenate([zeros, jnp.cumsum(absdev, axis=0)], axis=0)
    cv = jnp.concatenate([zeros, jnp.cumsum(s, axis=0)], axis=0)
    n_win = theta - beta + 1
    win_dev = cd[beta:] - cd[:n_win]
    win_sum = cv[beta:] - cv[:n_win]
    w = jnp.argmin(win_dev, axis=0)
    best = jnp.take_along_axis(win_sum, w[None], axis=0)[0]
    return best / beta


def coordinate_phase_nd(selected: jnp.ndarray, f: int,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Bulyan's coordinate-wise phase over arbitrary trailing dims.

    Args:
      selected: ``(theta, *dims)`` stack of phase-1-selected gradients.
      f: Byzantine bound; requires ``beta = theta - 2f >= 1``.
      window: caps the number of coordinates processed at once (the sort
        + two cumsums need O(theta * window) workspace); ``None``
        processes every coordinate in one shot, preserving the input's
        sharding.

    Returns:
      ``(*dims,)`` — per coordinate, the mean of the beta values closest
      to the median (the contiguous-window argmin form).
    """
    theta = selected.shape[0]
    beta = theta - 2 * f
    if beta < 1:
        raise ValueError(
            f"beta = theta - 2f must be >= 1 (theta={theta}, f={f})")
    trailing = selected.shape[1:]
    d = math.prod(trailing)
    with named_span("agg/coordinate"):
        if window is None or window <= 0 or d <= window:
            return _phase_nd(selected, f)
        flat = selected.reshape(theta, d)
        chunks = [_phase_nd(flat[:, s:s + window], f)
                  for s in range(0, d, window)]
        return jnp.concatenate(chunks, axis=0).reshape(trailing)


# ---------------------------------------------------------------------------
# the engine: registry rules over the sharded distance/coordinate machinery
# ---------------------------------------------------------------------------

def distributed_aggregate(tree: Any, f: int, gar: str = "bulyan-krum", *,
                          agg_dtype: str = "native",
                          window: Optional[int] = None,
                          distance_backend: str = "auto", mesh=None,
                          state=None, history_window: Optional[int] = None,
                          rep_lr: Optional[float] = None,
                          rep_decay: Optional[float] = None):
    """Apply GAR ``gar`` across the leading worker axis of a stacked
    gradient pytree, leaf-wise (semantics contract: equals the flat core
    rule on ``stack_flatten`` of the same tree, see tests/test_dist.py).

    The rule is resolved through the unified registry (``repro.agg``);
    this function only owns the sharded machinery — the distance-backend
    dispatch and the windowed coordinate phase — and hands it to the
    rule's tree implementation through a ``TreeContext``.

    Args:
      tree: pytree of ``(n, *dims)`` worker-stacked gradients.
      f: Byzantine bound the rule defends against (quorum-checked).
      gar: any name ``repro.agg.resolve_rule`` accepts with a tree
        implementation — the registered rules, ``"bulyan-<base>"`` for
        distance-only bases (krum/geomed), and stateful
        ``"buffered-<base>"`` / ``"centered_clip_momentum"`` /
        ``"stale-<base>"`` (staleness weights read from the carried
        state's ``GradientBus``; see ``repro.agg.staleness``).
      agg_dtype: ``"native"`` (fp32) | ``"float32"`` | ``"bfloat16"`` —
        the accumulation dtype contract (see module docstring).
      window: coordinate-phase window for bulyan rules (see
        ``coordinate_phase_nd``).
      distance_backend: ``"xla"`` | ``"pallas"`` | ``"fused"`` |
        ``"auto"`` — how the (n, n) distance matrix of distance-based
        rules is computed (see ``pairwise_sq_dists_tree``; non-distance
        rules ignore it).  ``"fused"`` additionally reroutes the rule
        onto its ``fused-<base>`` megakernel composite when one exists
        (``repro.agg.fused.fused_name``); rules without a fused lowering
        (``brute``, ``average``, ...) run unchanged over the Pallas
        distance pass.
      mesh: optional device mesh for the shard-mapped Pallas path.
      state: carried ``AggState`` for stateful rules (``None``
        zero-initializes one in-graph); stateless rules ignore it.
      history_window: ``buffered-*`` sliding-window length (``None`` =
        registry default).
      rep_lr: ``reputation-*`` EMA rate (``None`` = registry default;
        other rules ignore it — see ``repro.agg.reputation``).
      rep_decay: ``reputation-*`` forgetting factor (same default rule).

    Returns:
      ``(aggregated pytree, DistAggResult)`` for stateless rules, and
      ``(aggregated pytree, DistAggResult, new_state)`` for stateful
      ones — so stateless callers keep the historic two-tuple.  The
      aggregate's leaves keep their input dtypes.
    """
    from repro.agg.registry import TreeContext, resolve_rule
    from repro.agg.specs import check_quorum
    from repro.agg.state import init_state

    n = _worker_count(tree)
    rule = resolve_rule(gar, history_window=history_window,
                        rep_lr=rep_lr, rep_decay=rep_decay)
    check_quorum(gar, n, f, distributed=True,
                 history_window=history_window)
    if resolve_distance_backend(distance_backend, mesh) == "fused":
        from repro.agg.fused import fused_name
        lowered = fused_name(gar)
        if lowered is not None:
            rule = resolve_rule(lowered, history_window=history_window,
                                rep_lr=rep_lr, rep_decay=rep_decay)
    cdt = _compute_dtype(agg_dtype)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out_dtypes = [leaf.dtype for leaf in leaves]

    def make_dists(ls):
        t = jax.tree_util.tree_unflatten(treedef, list(ls))
        return pairwise_sq_dists_tree(t, cdt,
                                      distance_backend=distance_backend,
                                      mesh=mesh)

    ctx = TreeContext(
        leaves=tuple(leaves), n=n, f=f, cdt=cdt, make_dists=make_dists,
        coordinate_phase=partial(coordinate_phase_nd, window=window))

    if rule.stateful:
        if state is None:
            state = init_state(rule, tree, flat=False)
        with named_span("agg/select"):
            out, new_state = rule.tree_fn(ctx, state)
    else:
        with named_span("agg/select"):
            out = rule.tree_fn(ctx)

    agg_tree = jax.tree_util.tree_unflatten(
        treedef, [a.astype(dt) for a, dt in zip(out.leaves, out_dtypes)])
    res = DistAggResult(out.selected, out.scores)
    if rule.stateful:
        return agg_tree, res, new_state
    return agg_tree, res


# ---------------------------------------------------------------------------
# per-leaf Byzantine injection
# ---------------------------------------------------------------------------

def _tree_coord_count(leaves) -> int:
    return sum(math.prod(l.shape[1:]) for l in leaves)


def _tree_delta_bar(honest_leaves) -> jnp.ndarray:
    """Paper §B.1 ``delta_bar`` over the concatenated coordinate space,
    accumulated per leaf: 2/sqrt(pi) * mean over coordinates of the
    per-coordinate std across honest workers."""
    total = jnp.zeros((), jnp.float32)
    count = 0
    for leaf in honest_leaves:
        x = leaf.astype(jnp.float32)
        sd = jnp.std(x, axis=0)
        total = total + jnp.sum(sd)
        count += math.prod(leaf.shape[1:])
    return 2.0 / jnp.sqrt(jnp.pi) * total / max(count, 1)


def inject_byzantine(tree: Any, f: int, attack: str, key=None, *,
                     gar_name: str = "krum", step=None, gamma=None,
                     scale: Optional[float] = None, eps: float = 0.5,
                     z: Optional[float] = None, target: int = 0,
                     coord=0, margin: float = 1.0,
                     direction: str = "ones", prev: Any = None,
                     hold: int = 0, build: int = 5) -> Any:
    """Replace the last ``f`` worker rows of every leaf with Byzantine
    submissions computed from the first ``n - f`` (honest) rows.

    All attacks run per-leaf — coordinate-wise attacks (signflip, alie,
    ipm, zero, mimic, random) are exactly their flat counterparts; the
    omniscient attacks use the paper's §B *closed-form* gamma (the exact
    in-graph bisection of ``repro.core.attacks`` needs the full rule — and
    hence the flat matrix — inside the search loop, so the distributed
    runtime uses the estimate the paper itself used).

    Args:
      tree: pytree of ``(n, *dims)`` worker-stacked gradients.
      f: number of rows to overwrite (``f <= 0`` is a no-op).
      attack: attack name (see module body for the registry).
      key: PRNG key for the ``random`` attack.
      gar_name/step/gamma/scale/eps/z/target/coord/margin/direction:
        per-attack parameters; ``coord`` indexes the concatenated
        coordinate space of the whole tree, or ``"rotate"`` / ``"top"``;
        ``direction`` is the linf attack's +-1 vector — ``"ones"`` or
        ``"anti"`` (against the sign of the honest mean), matching the
        flat ``repro.core.attacks.omniscient_linf``; for
        ``colluding_majority`` it picks the cluster offset instead
        (``"anti"`` = negated honest mean, anything else = random),
        matching the flat attack's ``direction``.
      prev/hold: the delay-exploiting attacks' parameters —
        ``stale_replay`` and ``slow_drift`` read ``prev``, a pytree of
        ``(f, *dims)`` leaves holding the adversary's previous bus
        submissions (threaded by the async step builders; ``None``
        degenerates both to mimic-the-mean), and ``stale_replay``
        re-records every ``hold`` steps (0 = freeze forever).
      build: the ``reputation_burn`` attack's build phase length —
        honest-mean submissions for ``step < build``, then
        ``-scale * mean`` (``colluding_majority`` instead reads ``eps``
        as its offset in delta_bar units; both match the flat
        ``repro.core.attacks`` forms).

    Returns:
      The tree with the last f rows of every leaf replaced, dtypes and
      shapes preserved exactly.
    """
    if f <= 0 or attack == "none":
        return tree
    n = _worker_count(tree)
    n_h = n - f
    if n_h < 1:
        raise ValueError(f"need at least one honest worker (n={n}, f={f})")
    if key is None:
        key = jax.random.PRNGKey(0)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    honest = [l[:n_h] for l in leaves]

    def _broadcast(byz_one, leaf):
        """(…) per-leaf Byzantine value -> f stacked rows, leaf dtype."""
        return jnp.broadcast_to(byz_one[None], (f,) + leaf.shape[1:]
                                ).astype(leaf.dtype)

    if attack == "signflip":
        s = 1.0 if scale is None else scale
        byz = [_broadcast(-s * jnp.mean(h.astype(jnp.float32), axis=0),
                          l) for h, l in zip(honest, leaves)]
    elif attack == "zero":
        byz = [jnp.zeros((f,) + l.shape[1:], l.dtype) for l in leaves]
    elif attack == "mimic":
        byz = [_broadcast(h[target], l) for h, l in zip(honest, leaves)]
    elif attack == "ipm":
        byz = [_broadcast(-eps * jnp.mean(h.astype(jnp.float32), axis=0), l)
               for h, l in zip(honest, leaves)]
    elif attack == "random":
        s = 10.0 if scale is None else scale  # core.random_noise default
        byz = [s * jax.random.normal(jax.random.fold_in(key, j),
                                     (f,) + l.shape[1:], l.dtype)
               for j, l in enumerate(leaves)]
    elif attack == "alie":
        if z is None:
            s = (n // 2) + 1 - f
            phi = max(min((n - f - s) / float(n - f), 1.0 - 1e-6), 1e-6)
            z = float(jax.scipy.special.ndtri(phi))
        byz = [_broadcast(jnp.mean(h.astype(jnp.float32), axis=0)
                          - z * jnp.std(h.astype(jnp.float32), axis=0), l)
               for h, l in zip(honest, leaves)]
    elif attack in ("stale_replay", "slow_drift"):
        means = [jnp.mean(h.astype(jnp.float32), axis=0) for h in honest]
        t = jnp.asarray(step if step is not None else 0, jnp.int32)
        prev_leaves = (jax.tree_util.tree_leaves(prev)
                       if prev is not None else [None] * len(leaves))
        if len(prev_leaves) != len(leaves):
            raise ValueError(
                "prev must mirror the gradient tree's flat leaf order")
        if attack == "stale_replay":
            s = 1.0 if scale is None else scale
            refresh = t == 0
            if hold > 0:
                refresh = refresh | (t % hold == 0)
            byz = [_broadcast(s * m, l) if p is None
                   else jnp.where(refresh, _broadcast(s * m, l),
                                  p.astype(l.dtype))
                   for m, l, p in zip(means, leaves, prev_leaves)]
        else:
            db = _tree_delta_bar(honest)
            if direction == "anti":
                es = [jnp.where(m == 0, 1.0, -jnp.sign(m)) for m in means]
            else:
                es = [jnp.ones_like(m) for m in means]
            byz = []
            for m, e, l, p in zip(means, es, leaves, prev_leaves):
                if p is None:
                    byz.append(_broadcast(m + eps * db * e, l))
                else:
                    drifted = p.astype(jnp.float32) + eps * db * e[None]
                    byz.append(jnp.where(t == 0, _broadcast(m, l),
                                         drifted).astype(l.dtype))
    elif attack == "reputation_burn":
        s = 3.0 if scale is None else scale
        t = jnp.asarray(step if step is not None else 0, jnp.int32)
        byz = [_broadcast(jnp.where(t < build, 1.0, -s)
                          * jnp.mean(h.astype(jnp.float32), axis=0), l)
               for h, l in zip(honest, leaves)]
    elif attack == "colluding_majority":
        # one unit direction over the concatenated coordinate space,
        # normalized by the global norm: random per-leaf gaussians, or
        # (direction="anti") the negated honest mean — the
        # descent-reversing worst case, as in the flat attack
        db = _tree_delta_bar(honest)
        if direction == "anti":
            dirs = [-jnp.mean(h.astype(jnp.float32), axis=0)
                    for h in honest]
        else:
            dirs = [jax.random.normal(jax.random.fold_in(key, j),
                                      l.shape[1:], jnp.float32)
                    for j, l in enumerate(leaves)]
        norm = jnp.sqrt(sum(jnp.sum(e * e) for e in dirs)) + 1e-12
        byz = [_broadcast(jnp.mean(h.astype(jnp.float32), axis=0)
                          + eps * db * e / norm, l)
               for h, e, l in zip(honest, dirs, leaves)]
    elif attack in ("omniscient_linf", "omniscient_lp"):
        d = _tree_coord_count(leaves)
        db = _tree_delta_bar(honest)
        means = [jnp.mean(h.astype(jnp.float32), axis=0) for h in honest]
        # gamma None and "closed" both mean the §B closed form here (the
        # exact bisection only exists on the flat path); margin applies to
        # the estimate only — an explicit gamma is used verbatim
        estimated = gamma is None or gamma == "closed"
        if attack == "omniscient_linf":
            # per-coordinate leeway ~ delta_bar (§3.3: poisoning every
            # coordinate forfeits the sqrt(d) amplification)
            g = (db * margin if estimated
                 else jnp.asarray(gamma, jnp.float32))
            if direction == "anti":
                # against the sign of the honest mean, zeros -> +1
                # (the flat reference's worst-case +-1 vector)
                es = [jnp.where(m == 0, 1.0, -jnp.sign(m)) for m in means]
            else:
                es = [jnp.ones_like(m) for m in means]
            byz = [_broadcast(m + g * e, l)
                   for m, e, l in zip(means, es, leaves)]
        else:
            # §3.2: one coordinate, gamma_m ~ d^{1/p} closed form (§B).
            # ``coord`` indexes the concatenated coordinate space of the
            # whole tree (same convention as the flat reference).
            from repro.core.attacks import _closed_gamma
            g = (_closed_gamma(gar_name, d, f, db) * margin if estimated
                 else jnp.asarray(gamma, jnp.float32))
            sign = jnp.asarray(1.0, jnp.float32)
            if coord == "rotate":
                c = (jnp.asarray(step, jnp.int32) if step is not None
                     else jnp.zeros((), jnp.int32)) % d
            elif coord == "top":
                # coordinate where the honest mean is largest in
                # magnitude, attacked against its sign
                sizes = [math.prod(l.shape[1:]) for l in leaves]
                offs_py = [0]
                for s_ in sizes[:-1]:
                    offs_py.append(offs_py[-1] + s_)
                maxes = jnp.stack([jnp.max(jnp.abs(m)) for m in means])
                arg = jnp.stack([jnp.argmax(jnp.abs(m.reshape(-1)))
                                 for m in means])
                vals = jnp.stack([m.reshape(-1)[a]
                                  for m, a in zip(means, arg)])
                j = jnp.argmax(maxes)
                c = (jnp.asarray(offs_py, jnp.int32)[j]
                     + arg[j].astype(jnp.int32))
                sign = -jnp.sign(vals[j])
            else:
                if isinstance(coord, int) and not 0 <= coord < d:
                    raise ValueError(
                        f"coord must be in [0, {d}), 'rotate' or 'top'; "
                        f"got {coord!r}")
                c = jnp.asarray(coord, jnp.int32)
            off = 0
            byz = []
            for m, l in zip(means, leaves):
                sz = math.prod(l.shape[1:])
                local = c - off
                hit = (local >= 0) & (local < sz)
                e = jnp.zeros((sz,), jnp.float32).at[
                    jnp.clip(local, 0, sz - 1)].set(
                        jnp.where(hit, sign, 0.0)).reshape(l.shape[1:])
                byz.append(_broadcast(m + g * e, l))
                off += sz
    else:
        raise KeyError(f"unknown distributed attack {attack!r}")

    out = [jnp.concatenate([l[:n_h], b], axis=0)
           for l, b in zip(leaves, byz)]
    return jax.tree_util.tree_unflatten(treedef, out)
