"""The sharded Byzantine train step.

One jit-able function runs the paper's full protocol: per-worker
forward/backward (vmap over the leading worker axis of the batch),
in-graph Byzantine injection on the stacked gradient tree, tree-aware
robust aggregation, optimizer update.  Sharding enters only through the
input/output shardings — the identical step function executes unsharded
on a single device (the semantics reference of ``tests/test_dist.py``)
and GSPMD-partitioned on a pod: the worker axis lives on ``data``, the
parameters on ``model``, and the per-leaf Gram contractions of
``repro.dist.robust`` become local partial products plus an (n, n)
all-reduce.

The single-host flat-matrix reference lives in ``repro.training.trainer``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.dist.robust import distributed_aggregate, inject_byzantine
from repro.models import forward
from repro.models.config import ModelConfig
from repro.optim import Optimizer

__all__ = ["DistByzantineSpec", "make_loss_fn", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class DistByzantineSpec:
    """Static configuration of the distributed Byzantine protocol.

    ``f`` is both the number of injected Byzantine workers and the bound
    the aggregation rule defends against (``declared_f`` overrides the
    latter).  The worker count is taken from the batch's leading axis at
    trace time; the quorum check runs then.

    ``distance_backend`` selects the (n, n) pairwise-distance
    implementation of distance-based GARs: ``"xla"`` (tensordot, GSPMD),
    ``"pallas"`` (the tiled kernel — shard-mapped when ``make_train_step``
    is given a mesh) or ``"auto"`` (pallas only on TPU *with* a
    model-parallel mesh threaded through, xla otherwise).  See
    ``repro.dist.robust.resolve_distance_backend``.
    """

    f: int
    gar: str = "bulyan-krum"
    attack: str = "none"
    attack_kwargs: tuple = ()          # (("gamma", 10.0), ...)
    agg_dtype: str = "native"          # native | float32 | bfloat16
    distance_backend: str = "auto"     # auto | xla | pallas
    declared_f: Optional[int] = None
    seed: int = 0

    @property
    def f_declared(self) -> int:
        return self.declared_f if self.declared_f is not None else self.f

    def validate(self, n_workers: int) -> None:
        from repro.dist.robust import _check_quorum
        _check_quorum(self.gar, n_workers, self.f_declared)


def make_loss_fn(cfg: ModelConfig, impl: str = "auto") -> Callable:
    """Token-level cross-entropy (fp32 logsumexp) plus the model's aux
    loss (MoE load balancing).  ``loss_fn(params, tokens, labels, extra)``.
    """

    def loss_fn(params, tokens, labels, extra=None):
        logits, aux = forward(params, cfg, tokens, extra, impl=impl)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - ll) + aux

    return loss_fn


def _global_norm(tree) -> jnp.ndarray:
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        x = leaf.astype(jnp.float32)
        total = total + jnp.sum(x * x)
    return jnp.sqrt(total)


def make_train_step(cfg: ModelConfig, spec: DistByzantineSpec,
                    optimizer: Optimizer, impl: str = "auto",
                    mesh=None) -> Callable:
    """Build ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)``.

    batch: ``{"tokens", "labels"[, "extra"]}`` with a leading worker axis
    ``(n_workers, per_worker_batch, ...)`` on every entry.  All n workers
    compute real gradients; when an attack is configured the last ``f``
    are overwritten in-graph by the omniscient adversary (it reads the
    honest gradients first, per the paper's threat model).

    ``mesh`` is only consulted by the Pallas distance backend (it pins the
    ``shard_map`` layout of the distance pass); the XLA backend keeps the
    step mesh-agnostic exactly as before — sharding enters via the
    input/output shardings the caller jits with.
    """
    loss_fn = make_loss_fn(cfg, impl)
    vg = jax.value_and_grad(loss_fn)

    def step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = batch.get("extra")
        n = tokens.shape[0]
        spec.validate(n)
        f = spec.f
        n_h = n - f

        if extra is None:
            losses, grads = jax.vmap(
                lambda t, l: vg(params, t, l))(tokens, labels)
        else:
            losses, grads = jax.vmap(
                lambda t, l, e: vg(params, t, l, e))(tokens, labels, extra)

        if spec.attack != "none" and f > 0:
            key = jax.random.fold_in(jax.random.PRNGKey(spec.seed),
                                     opt_state["step"])
            akw = dict(spec.attack_kwargs)
            akw.setdefault("gar_name", spec.gar)
            grads = inject_byzantine(grads, f, spec.attack, key=key,
                                     step=opt_state["step"], **akw)

        agg, res = distributed_aggregate(
            grads, spec.f_declared, spec.gar, agg_dtype=spec.agg_dtype,
            distance_backend=spec.distance_backend, mesh=mesh)
        new_params, new_state = optimizer.update(agg, opt_state, params)

        honest_mean = jax.tree_util.tree_map(
            lambda g: jnp.mean(g[:n_h].astype(jnp.float32), axis=0), grads)
        dev = jax.tree_util.tree_map(
            lambda a, m: a.astype(jnp.float32) - m, agg, honest_mean)
        metrics = {
            "loss": jnp.mean(losses[:n_h]),
            "grad_norm": _global_norm(agg),
            "agg_dev": _global_norm(dev),
            "byz_weight": (jnp.sum(res.selected[n_h:]) if f > 0
                           else jnp.zeros((), jnp.float32)),
        }
        return new_params, new_state, metrics

    return step
