"""The sharded Byzantine train step.

One jit-able function runs the paper's full protocol: per-worker
forward/backward (vmap over the leading worker axis of the batch),
in-graph Byzantine injection on the stacked gradient tree, tree-aware
robust aggregation, optimizer update.  Sharding enters only through the
input/output shardings — the identical step function executes unsharded
on a single device (the semantics reference of ``tests/test_dist.py``)
and GSPMD-partitioned on a pod: the worker axis lives on ``data``, the
parameters on ``model``, and the per-leaf Gram contractions of
``repro.dist.robust`` become local partial products plus an (n, n)
all-reduce.

The single-host flat-matrix reference lives in ``repro.training.trainer``.
The asynchronous variant of this step — the same protocol without the
per-step barrier, aggregating a ``GradientBus`` of versioned per-worker
slots under bounded staleness — lives in ``repro.dist.async_train``
(``make_async_train_step`` reuses ``make_loss_fn`` and reproduces this
step bitwise at ``async_tau = 0``).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.agg.specs import AggSpec
from repro.agg.state import init_state
from repro.dist.robust import distributed_aggregate, inject_byzantine
from repro.models import forward
from repro.models.config import ModelConfig
from repro.obs.schema import core_metrics, global_norm, selection_weight
from repro.optim import Optimizer

__all__ = ["DistByzantineSpec", "init_agg_state", "make_loss_fn",
           "make_train_step"]

#: deprecation alias — the sharded spec is now the unified
#: ``repro.agg.AggSpec`` (same fields plus the single-host ones);
#: ``spec.validate(n_workers)`` keeps its historic trace-time call form
#: (the step builders additionally pass ``distributed=True`` to demand a
#: tree implementation — no longer inferred from the explicit count).
DistByzantineSpec = AggSpec


def init_agg_state(spec: AggSpec, params, n_workers: int):
    """Zeroed ``AggState`` for a stateful GAR on the sharded path.

    Args:
      spec: the protocol spec (``gar`` / ``history_window`` select the
        rule and its window).
      params: the parameter pytree (or a ``ShapeDtypeStruct`` tree —
        only shapes are read, so this composes with ``jax.eval_shape``).
      n_workers: worker count, the leading axis of the gradient stacks.

    Returns:
      An ``AggState`` sized for per-worker gradient stacks of
      ``params``'s shapes, or ``None`` when the rule is stateless.
    """
    rule = spec.rule()
    if not rule.stateful:
        return None
    template = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct((n_workers,) + tuple(p.shape),
                                       p.dtype), params)
    return init_state(rule, template, flat=False)


def make_loss_fn(cfg: ModelConfig, impl: str = "auto") -> Callable:
    """Token-level cross-entropy (fp32 logsumexp) plus the model's aux
    loss (MoE load balancing).  ``loss_fn(params, tokens, labels, extra)``.
    """

    def loss_fn(params, tokens, labels, extra=None):
        logits, aux = forward(params, cfg, tokens, extra, impl=impl)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - ll) + aux

    return loss_fn


# per-leaf fp32 norm accumulation now lives in the shared metrics
# schema; the historic private name stays for the async step's import
_global_norm = global_norm


def make_train_step(cfg: ModelConfig, spec: DistByzantineSpec,
                    optimizer: Optimizer, impl: str = "auto",
                    mesh=None) -> Callable:
    """Build the jit-able sharded Byzantine train step.

    Stateless GARs get the historic signature ``step(params, opt_state,
    batch) -> (params, opt_state, metrics)``; when ``spec.gar`` resolves
    to a stateful rule (``buffered-*`` / ``centered_clip_momentum`` /
    ``reputation-*``) the step becomes ``step(params, opt_state, batch,
    agg_state) -> (params, opt_state, metrics, agg_state)`` with the
    ``AggState`` carried by the caller (see ``init_agg_state``) —
    stateless runs pay nothing.  ``reputation-*`` runs additionally
    honor ``spec.aux_batch`` (clean-batch ByGARS scoring overrides the
    agreement update) and a set ``spec.rep_lr`` (the aggregate is scaled
    by ``step_size_multiplier`` before the optimizer — reported as
    ``metrics["step_scale"]``).

    batch: ``{"tokens", "labels"[, "extra"]}`` with a leading worker axis
    ``(n_workers, per_worker_batch, ...)`` on every entry.  All n workers
    compute real gradients; when an attack is configured the last ``f``
    are overwritten in-graph by the omniscient adversary (it reads the
    honest gradients first, per the paper's threat model).

    ``mesh`` is only consulted by the Pallas distance backend (it pins the
    ``shard_map`` layout of the distance pass); the XLA backend keeps the
    step mesh-agnostic exactly as before — sharding enters via the
    input/output shardings the caller jits with.
    """
    loss_fn = make_loss_fn(cfg, impl)
    vg = jax.value_and_grad(loss_fn)
    rule = spec.rule()
    stateful = rule.stateful
    reputed = "reputation" in rule.state_fields

    def run_step(params, opt_state, batch, agg_state):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = batch.get("extra")
        n = tokens.shape[0]
        spec.validate(n, distributed=True)
        f = spec.f
        n_h = n - f

        if extra is None:
            losses, grads = jax.vmap(
                lambda t, l: vg(params, t, l))(tokens, labels)
        else:
            losses, grads = jax.vmap(
                lambda t, l, e: vg(params, t, l, e))(tokens, labels, extra)

        if spec.attack != "none" and f > 0:
            key = jax.random.fold_in(jax.random.PRNGKey(spec.seed),
                                     opt_state["step"])
            akw = dict(spec.attack_kwargs)
            akw.setdefault("gar_name", spec.gar)
            grads = inject_byzantine(grads, f, spec.attack, key=key,
                                     step=opt_state["step"], **akw)

        out = distributed_aggregate(
            grads, spec.f_declared, spec.effective_gar,
            agg_dtype=spec.agg_dtype,
            distance_backend=spec.distance_backend, mesh=mesh,
            state=agg_state, history_window=spec.history_window,
            rep_lr=spec.rep_lr, rep_decay=spec.rep_decay)
        agg, res = out[0], out[1]
        new_agg_state = out[2] if stateful else None

        step_scale = jnp.ones((), jnp.float32)
        if reputed:
            from repro.agg.reputation import (
                DEFAULT_REP_DECAY, DEFAULT_REP_LR, step_size_multiplier,
                tree_reputation_scores, update_reputation)
            if spec.aux_batch is not None:
                # ByGARS proper: score raw submissions against the clean
                # auxiliary gradient, overriding the rule's own
                # agreement-with-the-aggregate update — the only signal
                # a colluding majority cannot vote on
                aux = tuple(spec.aux_batch)
                _, clean = vg(params, *aux)
                scores = tree_reputation_scores(
                    jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(clean))
                lr = (DEFAULT_REP_LR if spec.rep_lr is None
                      else spec.rep_lr)
                decay = (DEFAULT_REP_DECAY if spec.rep_decay is None
                         else spec.rep_decay)
                new_agg_state = new_agg_state._replace(
                    reputation=update_reputation(
                        agg_state.reputation, scores, lr, decay))
            if spec.rep_lr:
                # staleness-adaptive step size (Alistarh et al.): the
                # same carried trust scales the update magnitude
                step_scale = step_size_multiplier(new_agg_state)
                agg = jax.tree_util.tree_map(
                    lambda a: (a.astype(jnp.float32)
                               * step_scale).astype(a.dtype), agg)
        new_params, new_state = optimizer.update(agg, opt_state, params)

        honest_mean = jax.tree_util.tree_map(
            lambda g: jnp.mean(g[:n_h].astype(jnp.float32), axis=0), grads)
        dev = jax.tree_util.tree_map(
            lambda a, m: a.astype(jnp.float32) - m, agg, honest_mean)
        metrics = core_metrics(
            loss=jnp.mean(losses[:n_h]),
            grad_norm=global_norm(agg),
            agg_dev=global_norm(dev),
            byz_weight=selection_weight(res.selected, n_h),
            step_scale=step_scale if reputed else None)
        return new_params, new_state, metrics, new_agg_state

    if stateful:
        return run_step

    def step(params, opt_state, batch):
        return run_step(params, opt_state, batch, None)[:3]

    return step
