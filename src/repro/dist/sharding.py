"""Sharding rules: pytrees -> NamedSharding / PartitionSpec.

Rules are deliberately structural (shape + tree path), not per-arch
tables: every assigned architecture's parameter tree flows through the
same three functions.  A dimension is only ever sharded when it divides
evenly by the mesh axis — anything else is replicated, which is always
correct and lets the reduced CPU configs reuse the production rules.

``LEGACY_RULES`` is the pre-iteration baseline (shard the last dim only)
kept for A/B dry-run comparisons (``repro.launch.dryrun
--legacy-sharding``).
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.mesh import mesh_axis_sizes

__all__ = ["LEGACY_RULES", "batch_pspec", "cache_shardings",
           "ensemble_cache_shardings", "ensemble_param_shardings",
           "gram_pspec", "param_shardings"]

#: pre-iteration parameter rules (A/B baseline; see launch.dryrun)
LEGACY_RULES = False


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _leaf_pspec(path, shape: Sequence[int], model: int) -> P:
    """Parameter rule: shard one dimension over ``model``.

    The largest evenly-divisible dimension wins (ties -> the later dim, so
    square projections shard their output side).  Scalars, vectors (norm
    gains, biases) and anything indivisible stay replicated.  The leading
    stacked-period axis of scanned layer parameters is never sharded —
    ``lax.scan`` unstacks along it every step.
    """
    if model <= 1 or len(shape) < 2:
        return P()
    if LEGACY_RULES:
        if shape[-1] % model == 0 and shape[-1] >= model:
            return P(*([None] * (len(shape) - 1) + ["model"]))
        return P()
    in_periods = "periods" in _path_keys(path)
    order = sorted(range(len(shape)), key=lambda i: (-shape[i], -i))
    for i in order:
        if in_periods and i == 0:
            continue
        if shape[i] >= model and shape[i] % model == 0:
            spec = [None] * len(shape)
            spec[i] = "model"
            return P(*spec)
    return P()


def param_shardings(tree: Any, mesh) -> Any:
    """NamedSharding pytree for parameters / optimizer state.

    Parameters are replicated across ``data`` (each Byzantine worker holds
    a full replica — the paper's protocol) and tensor-sharded across
    ``model``.  Optimizer state mirrors its parameter's layout because it
    has the parameter's shape; scalar state (step counters) replicates.

    Args:
      tree: parameter (or optimizer-state) pytree of arrays.
      mesh: the device mesh.

    Returns:
      A pytree of ``NamedSharding`` with the structure of ``tree``.
    """
    model = mesh_axis_sizes(mesh).get("model", 1)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _leaf_pspec(path, leaf.shape, model)), tree)


def _first_fit(dim: int, sizes, options) -> Any:
    """First axis combo (in preference order) that evenly divides ``dim``."""
    for axes in options:
        if not all(a in sizes for a in axes):
            continue
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if prod > 1 and dim % prod == 0:
            return axes[0] if len(axes) == 1 else tuple(axes)
    return None


def batch_pspec(shape: Sequence[int], mesh, worker_axis: bool = True) -> P:
    """PartitionSpec for model inputs.

    Args:
      shape: the input's global shape.
      mesh: the device mesh.
      worker_axis: ``True`` for training inputs ``(n_workers,
        per_worker, ...)`` — the worker axis maps onto ``data`` (one
        worker per data slice) and the per-worker batch additionally
        splits over ``pod`` when present.  ``False`` for serving inputs
        ``(batch, ...)`` — batch spreads over every data-parallel axis
        that divides it.

    Returns:
      ``PartitionSpec`` for the input (trailing ``None`` entries pruned).
    """
    sizes = mesh_axis_sizes(mesh)
    if not shape:
        return P()
    spec = [None] * len(shape)
    if worker_axis:
        spec[0] = _first_fit(shape[0], sizes, [("data",)])
        if len(shape) > 1:
            spec[1] = _first_fit(shape[1], sizes, [("pod",)])
    else:
        spec[0] = _first_fit(shape[0], sizes,
                             [("pod", "data"), ("data",), ("pod",)])
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def gram_pspec(shape: Sequence[int], mesh, path=()) -> P:
    """PartitionSpec for a stacked-gradient leaf entering the shard-mapped
    Pallas distance pass (``repro.dist.robust`` with
    ``distance_backend="pallas"``).

    Args:
      shape: the leaf's global shape ``(n_workers, *param_dims)``.
      mesh: the device mesh (only the ``model`` axis matters here).
      path: the leaf's tree path (as from ``tree_flatten_with_path``);
        used to recognize scanned-layer ``periods`` leaves.

    Returns:
      ``PartitionSpec`` with the worker axis replicated (every shard's
      local Gram contraction needs all n rows of its coordinate slice) and
      the largest evenly-divisible trailing dim sharded over ``model`` —
      the same rule as ``param_shardings`` including the never-shard rule
      for the stacked-period axis (index 1 here, behind the worker axis),
      so gradient leaves enter the kernel in the layout GSPMD already
      gave them.  Indivisible leaves stay fully replicated, which is
      always correct.
    """
    model = mesh_axis_sizes(mesh).get("model", 1)
    spec = [None] * len(shape)
    if model > 1 and len(shape) >= 2:
        in_periods = "periods" in _path_keys(path)
        order = sorted(range(1, len(shape)), key=lambda i: (-shape[i], -i))
        for i in order:
            if in_periods and i == 1:
                continue
            if shape[i] >= model and shape[i] % model == 0:
                spec[i] = "model"
                break
    return P(*spec)


def ensemble_param_shardings(tree: Any, mesh) -> Any:
    """NamedSharding pytree for replica-stacked ensemble parameters.

    The leading replica axis (``repro.dist.serve_robust`` layout) maps
    onto ``data`` — each data slice serves a subset of replicas, the
    serving analogue of "one worker per data slice" in training — while
    the inner parameter dimensions follow the exact ``param_shardings``
    rule over ``model`` (including the never-shard rule for the stacked
    period axis).  A replica count that does not divide the ``data``
    axis replicates, which is always correct.

    Args:
      tree: ``(n_replicas, *dims)``-stacked parameter pytree (arrays or
        ``ShapeDtypeStruct``s — only shapes are read).
      mesh: the device mesh.

    Returns:
      A pytree of ``NamedSharding`` with the structure of ``tree``.
    """
    sizes = mesh_axis_sizes(mesh)
    data = sizes.get("data", 1)
    model = sizes.get("model", 1)

    def spec_for(path, leaf):
        inner = _leaf_pspec(path, leaf.shape[1:], model)
        entries = list(inner) + [None] * (len(leaf.shape) - 1 - len(inner))
        lead = ("data" if data > 1 and leaf.shape[0] % data == 0
                and leaf.shape[0] >= data else None)
        return NamedSharding(mesh, P(lead, *entries))

    return jax.tree_util.tree_map_with_path(spec_for, tree)


def ensemble_cache_shardings(cache: Any, mesh) -> Any:
    """NamedSharding pytree for replica-stacked decode caches.

    Every leaf of the ensemble cache carries a leading replica axis
    (periods: ``(n_replicas, n_periods, B, ...)``, tail:
    ``(n_replicas, B, ...)``); it shards over ``data`` alongside the
    replica axis of the parameters so a replica's cache lives with its
    weights.  Everything else stays replicated (KV heads are usually too
    few to split the ``model`` axis, exactly as in ``cache_shardings``).

    Args:
      cache: replica-stacked decode-cache pytree.
      mesh: the device mesh.

    Returns:
      A pytree of ``NamedSharding`` with the structure of ``cache``.
    """
    data = mesh_axis_sizes(mesh).get("data", 1)

    def spec_for(leaf):
        if (leaf.ndim >= 1 and data > 1 and leaf.shape[0] % data == 0
                and leaf.shape[0] >= data):
            return NamedSharding(mesh, P("data"))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec_for, cache)


def cache_shardings(cache: Any, mesh) -> Any:
    """NamedSharding pytree for decode caches.

    Cache structure (see ``repro.models.decode.init_cache``): ``periods``
    leaves are period-stacked ``(n_periods, B, ...)``; ``tail`` leaves are
    ``(B, ...)``.  The batch axis shards over the data-parallel axes; the
    rest follows the activations (replicated over ``model`` — KV heads are
    usually too few to split a 16-way axis).

    Args:
      cache: decode-cache pytree.
      mesh: the device mesh.

    Returns:
      A pytree of ``NamedSharding`` with the structure of ``cache``.
    """
    sizes = mesh_axis_sizes(mesh)

    def spec_for(path, leaf):
        keys = _path_keys(path)
        batch_dim = 1 if "periods" in keys else 0
        shape = leaf.shape
        if len(shape) <= batch_dim:
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        spec[batch_dim] = _first_fit(shape[batch_dim], sizes,
                                     [("pod", "data"), ("data",), ("pod",)])
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, cache)
