"""Device meshes.

Importing this module never touches jax device state — meshes are built
inside functions so the test suite's single-CPU processes stay single-CPU
and the 512-device dry-run subprocess owns its own world.

Axis convention (matches the sharding rules in ``repro.dist.sharding``):

  data   Byzantine workers — one worker per ``data`` slice; robust
         aggregation reduces over this axis
  model  tensor parallelism within one worker's replica
  pod    optional outermost axis (multi-pod dry-runs); used for extra
         batch parallelism inside each worker
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["make_host_mesh", "make_production_mesh", "mesh_axis_sizes"]

_DEFAULT_NAMES = ("data", "model")


def make_host_mesh(shape: Optional[Tuple[int, ...]] = None,
                   axis_names: Optional[Sequence[str]] = None):
    """Mesh over the host's visible devices (CPU smoke / subprocess tests).

    Args:
      shape: device-grid shape, e.g. ``(4, 2)``; ``None`` puts every
        device on the ``data`` axis with a trivial ``model`` axis — the
        pure data-parallel layout.
      axis_names: one name per mesh dim; defaults to ``("data", "model")``
        (2-d) or ``("pod", "data", "model")`` (3-d).

    Returns:
      ``jax.sharding.Mesh`` over the first ``prod(shape)`` host devices.
    """
    import jax

    devices = jax.devices()
    if shape is None:
        shape = (len(devices), 1)
    if axis_names is None:
        if len(shape) == 3:
            axis_names = ("pod",) + _DEFAULT_NAMES
        else:
            axis_names = _DEFAULT_NAMES[:len(shape)]
    if len(axis_names) != len(shape):
        raise ValueError(f"{len(shape)}-d mesh needs {len(shape)} axis "
                         f"names, got {axis_names!r}")
    n = math.prod(shape)
    if n > len(devices):
        raise ValueError(
            f"mesh shape {shape} needs {n} devices, have {len(devices)}")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, tuple(axis_names))


def make_production_mesh(multi_pod: bool = False):
    """The assignment's production meshes.

    single pod:  (16, 16)      ``("data", "model")``  — 256 chips
    multi-pod:   (2, 16, 16)   ``("pod", "data", "model")`` — 512 chips

    The dry-run process initializes 512 host placeholder devices; the
    single-pod mesh uses the first 256 of them.
    """
    if multi_pod:
        return make_host_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_host_mesh((16, 16), ("data", "model"))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    """Axis-name -> size mapping of a mesh.

    Args:
      mesh: a ``jax.sharding.Mesh``.

    Returns:
      ``{axis name: size}``, e.g. ``{"data": 16, "model": 16}``.
    """
    return dict(zip(mesh.axis_names, mesh.devices.shape))
