"""Single-host serving: the continuous-batching engine.

``ServingEngine`` drives the model zoo's prefill/decode path with
fixed-slot continuous batching; its ``ensemble=`` mode turns it into the
Byzantine-resilient ensemble server built on ``repro.dist.serve_robust``
(robust logits aggregation per decode step through the ``repro.agg``
registry).  Architecture notes live in docs/serving.md.
"""
from repro.serving.engine import Request, ServingEngine

__all__ = ["Request", "ServingEngine"]
