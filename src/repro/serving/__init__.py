"""Single-host serving: the continuous-batching engine + speculation.

``ServingEngine`` drives the model zoo's prefill/decode path with
fixed-slot continuous batching; its ``ensemble=`` mode turns it into the
Byzantine-resilient ensemble server built on ``repro.dist.serve_robust``
(robust logits aggregation per decode step through the ``repro.agg``
registry), and ``ensemble.speculative_k`` switches that server to robust
speculative decoding (``repro.serving.speculative``: a draft replica
proposes, the aggregate verifies).  Architecture notes live in
docs/serving.md.
"""
from repro.serving.engine import Request, ServingEngine
from repro.serving.speculative import (accept_block, draft_cache_view,
                                       make_draft_propose)

__all__ = ["Request", "ServingEngine", "accept_block", "draft_cache_view",
           "make_draft_propose"]
