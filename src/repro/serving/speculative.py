"""Robust speculative decoding: draft proposal + Byzantine-safe acceptance.

Speculative decoding splits serving into a cheap **draft** pass and a
batched **verify** pass.  Here the draft is a *single replica* of the
ensemble (``spec.draft_replica``) decoding ``k - 1`` tokens greedily and
autoregressively; the ensemble then scores the whole block in one
``repro.dist.serve_robust.make_robust_verify_step`` call — ``n`` replica
forwards over ``(B, k)`` tokens, aggregated per position through the
unchanged ``repro.agg`` registry.

The Byzantine contract is carried entirely by the **acceptance rule**
(:func:`accept_block`): a draft token is emitted only if it survives the
*robustly aggregated* verifier distribution — argmax (or a logit-margin
threshold) on the aggregate, never on any single replica.  Consequences:

* a poisoned draft can only *propose* bad tokens; every proposal is
  checked against the aggregate, so collusion with the drafting replica
  costs throughput (rejected blocks) but never changes the accepted
  stream;
* ``f`` poisoned *verifier* replicas are exactly the per-token serving
  threat model — the aggregation rule bounds their influence on the
  verdict the same way it bounds it on the per-token path.

**Block convention** — a verify block of length ``k`` is
``[t0, d1, ..., d_{k-1}]``: the last emitted token followed by the
draft's proposals.  Fed at positions ``p .. p+k-1``, the aggregated
logits ``A_0 .. A_{k-1}`` predict tokens at ``p+1 .. p+k``; proposal
``d_{j+1}`` is accepted iff it survives ``A_j``, and the first rejected
position is replaced by the aggregate's own argmax.  Every block
therefore emits between 1 and ``k`` tokens, and at ``k = 1`` the block
is just ``[t0]`` — no drafting at all, one aggregation over
``(n, B, 1, vocab)`` — which makes the ``k = 1`` stream *bitwise
identical* to the per-token path by construction.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.models import decode_step
from repro.models.config import ModelConfig

__all__ = ["accept_block", "draft_cache_view", "make_draft_propose"]


def make_draft_propose(cfg: ModelConfig, k: int) -> Callable:
    """Build the jit-able greedy draft proposer for block length ``k``.

    The returned ``propose(draft_params, draft_cache, token, pos) ->
    (block, new_draft_cache)`` rolls the single draft replica forward
    ``k - 1`` greedy steps from the last emitted ``token`` — a
    ``lax.scan`` of ``decode_step`` — and returns the verify block
    ``[t0, d1, ..., d_{k-1}]`` of shape ``(B, k)``.  At ``k = 1`` no
    draft model runs at all: the block is just ``token[:, None]`` and
    the cache passes through untouched (the draft replica cannot touch a
    ``k = 1`` stream even in principle).

    The draft cache stays consistent across blocks without rollback:
    entries the draft wrote for later-rejected proposals sit strictly
    above the slot's accepted position, are masked out by the per-slot
    ``valid_len`` of ``decode_step``, and are overwritten by the next
    block's writes (which restart from the corrected token).

    Args:
      cfg: draft model configuration (the ensemble's shared ``cfg``).
      k: verify-block length (``>= 1``).

    Returns:
      The ``propose`` closure; ``token`` is ``(B,)`` int32 and ``pos``
      the ``(B,)`` per-slot position of ``token``.
    """
    if k < 1:
        raise ValueError(f"speculative block length must be >= 1, got {k}")
    if k == 1:
        def propose_identity(draft_params, draft_cache, token, pos):
            del draft_params, pos
            return token[:, None], draft_cache
        return propose_identity

    def propose(draft_params, draft_cache, token, pos):
        def body(carry, _):
            tok, cache, p = carry
            logits, cache = decode_step(draft_params, cfg, cache,
                                        tok[:, None], p)
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(token.dtype)
            return (nxt, cache, p + 1), nxt
        (_, new_cache, _), drafts = jax.lax.scan(
            body, (token, draft_cache, pos), None, length=k - 1)
        block = jnp.concatenate([token[:, None], jnp.moveaxis(drafts, 0, 1)],
                                axis=1)
        return block, new_cache

    return propose


def accept_block(block: jnp.ndarray, agg_logits: jnp.ndarray, *,
                 margin: float = 0.0
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Byzantine-safe acceptance: test draft tokens against the aggregate.

    Position ``j`` of the block was fed at sequence position ``p + j``,
    so ``agg_logits[:, j]`` is the robust ensemble's distribution for
    the token at ``p + j + 1``.  Proposal ``block[:, j+1]`` is accepted
    iff its aggregated logit is within ``margin`` of that distribution's
    maximum (``margin = 0``: the proposal must *be* an argmax).  The
    emitted stream is the longest accepted prefix plus one correction —
    the aggregate's own argmax at the first rejected position — so every
    call emits between 1 and ``k`` tokens per slot and the accepted
    stream never depends on any single replica's logits.

    Args:
      block: ``(B, k)`` verify block ``[t0, d1, ..., d_{k-1}]``.
      agg_logits: ``(B, k, vocab)`` robustly aggregated verifier logits.
      margin: acceptance slack in logit units (``0.0`` = exact argmax
        survival; larger values accept near-argmax proposals and only
        widen acceptance, never the attack surface — every emitted token
        still carries an aggregated logit within ``margin`` of the max).

    Returns:
      ``(emitted, count, verifier_argmax)`` — ``emitted`` is ``(B, k)``
      int32 whose first ``count[b]`` entries are slot ``b``'s tokens for
      positions ``p+1 ..`` (entries past ``count`` are padding),
      ``count`` is ``(B,)`` int32 in ``[1, k]``, and ``verifier_argmax``
      the ``(B, k)`` argmax of ``agg_logits`` (diagnostics / the
      ``k = 1`` greedy token).
    """
    b, k = block.shape
    v = jnp.argmax(agg_logits, axis=-1).astype(jnp.int32)     # (B, k)
    if k == 1:
        return v, jnp.ones((b,), jnp.int32), v
    drafts = block[:, 1:].astype(jnp.int32)                   # (B, k-1)
    scored = agg_logits[:, :-1, :]                            # (B, k-1, V)
    top = jnp.max(scored, axis=-1)
    dscore = jnp.take_along_axis(scored, drafts[..., None],
                                 axis=-1)[..., 0]
    ok = dscore >= top - jnp.float32(margin)                  # (B, k-1)
    prefix = jnp.cumprod(ok.astype(jnp.int32), axis=1)
    m = jnp.sum(prefix, axis=1)                               # accepted, 0..k-1
    count = m + 1
    cols = jnp.arange(k)[None, :]
    drafts_pad = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)
    emitted = jnp.where(cols < m[:, None], drafts_pad, v)
    return emitted, count.astype(jnp.int32), v


def draft_cache_view(stacked_cache: Any, replica: int) -> Any:
    """Slice one replica's cache out of a replica-stacked cache pytree.

    Used at admission time: the engine prefills a request's prompt once
    per replica (the robust prefill step) and splices replica
    ``spec.draft_replica``'s slice into the engine's standalone draft
    cache, so the draft decodes from exactly the context its own replica
    computed.

    Args:
      stacked_cache: cache pytree with a leading ``(n_replicas,)`` axis
        on every leaf (see ``repro.dist.serve_robust.replicate_cache``).
      replica: which replica's slice to take.

    Returns:
      The cache pytree without the replica axis.
    """
    return jax.tree_util.tree_map(lambda x: x[replica], stacked_cache)
