"""Batched serving engine: fixed-slot continuous batching over the decode
path, with an optional Byzantine-resilient ensemble mode.

Slots hold independent sequences; each engine step decodes one token for
every active slot (a single jit'd ``decode_step`` on the full batch).  New
requests are admitted into free slots via per-slot prefill.  This is the
"serve a small model with batched requests" driver of deliverable (b) and
exercises caches/positions exactly as the decode dry-run shapes do.

Each slot carries its own position counter (mixed-length batching ropes
and cache-writes per slot).  Admission is continuous: requests queue via
:meth:`ServingEngine.submit` and every :meth:`ServingEngine.step` drains
the queue into freed slots *before* decoding, so a slot vacated by a
finished request is refilled mid-stream without the caller orchestrating
anything.  Simplifications vs a production scheduler: no paged KV;
prefill runs at admission time on the slot's sub-batch; greedy sampling.

**Ensemble mode** (``ensemble=AggSpec(...)``): ``params`` is a
replica-stacked pytree (leading ``(n_replicas,)`` axis on every leaf, see
``repro.dist.serve_robust``), caches are kept per replica, and every
decode step aggregates the ``(n_replicas, n_slots, vocab)`` logits stack
through the ``repro.agg`` registry before sampling — Krum/Bulyan reject a
poisoned replica's distribution; stateful rules thread an ``AggState``
across tokens via ``self.agg_state``.  See docs/serving.md for the
architecture and the AggState-across-tokens contract.

**Speculative mode** (``ensemble.speculative_k >= 1``): each step drafts
a ``k``-token block on replica ``ensemble.draft_replica``, verifies all
``k`` positions in one batched robust-aggregation step
(``make_robust_verify_step``), and emits the longest draft prefix that
survives the aggregate plus one corrected token
(``repro.serving.speculative.accept_block``) — 1..k tokens per step per
slot.  ``speculative_k = 1`` runs the same machinery with no draft at
all and reproduces the per-token stream bitwise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus generation bookkeeping.

    ``generated`` accumulates sampled token ids (filled by the engine);
    ``done`` flips when ``max_new_tokens`` have been produced.
    """

    rid: int
    prompt: np.ndarray           # (S0,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    """Fixed-slot continuous-batching engine (optionally ensemble-robust).

    Plain mode: ``params`` is one parameter pytree and each step is one
    jit'd ``decode_step`` over all slots.  Ensemble mode (``ensemble=``
    an ``repro.agg.AggSpec``): ``params`` is a replica-stacked pytree (or
    a list of per-replica pytrees, stacked on entry), each step decodes
    every replica and aggregates the logits stack through
    ``spec.gar`` before greedy sampling; ``self.agg_state`` carries the
    ``AggState`` of stateful rules across tokens.

    Host-side counters (``positions``, ``last_token``) are int32 — the
    dtype the jit'd steps consume — so no implicit int64 promotion
    happens at the host/device boundary.
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 cache_len: int = 512, sampler: str = "greedy",
                 ensemble=None, mesh=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.ensemble = ensemble
        self.positions = np.zeros((n_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.pending: List[Request] = []
        self.last_token = np.zeros((n_slots,), np.int32)
        self.sampler = sampler
        self.agg_state = None
        self.spec_k = 0
        self.accept_counts: List[np.ndarray] = []
        if ensemble is None:
            self.params = params
            self.cache = init_cache(cfg, n_slots, cache_len)
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
            return
        # -- ensemble mode ----------------------------------------------------
        from repro.dist.serve_robust import (init_ensemble_state,
                                             make_robust_prefill_step,
                                             make_robust_serve_step,
                                             replicate_cache,
                                             stack_replicas)
        if isinstance(params, (list, tuple)):
            params = stack_replicas(params)
        self.params = params
        self.n_replicas = jax.tree_util.tree_leaves(params)[0].shape[0]
        self.cache = replicate_cache(init_cache(cfg, n_slots, cache_len),
                                     self.n_replicas)
        self.agg_state = init_ensemble_state(
            ensemble, self.n_replicas, n_slots, cfg.vocab_size)
        self._decode = jax.jit(
            make_robust_serve_step(cfg, ensemble, mesh=mesh))
        self._ens_prefill = make_robust_prefill_step(
            cfg, ensemble, cache_len=cache_len, mesh=mesh)
        # -- speculative mode -------------------------------------------------
        k = int(getattr(ensemble, "speculative_k", 0) or 0)
        if k < 1:
            return
        from repro.dist.serve_robust import make_robust_verify_step
        from repro.serving.speculative import accept_block, make_draft_propose
        self.spec_k = k
        self.draft_replica = int(ensemble.draft_replica)
        if not 0 <= self.draft_replica < self.n_replicas:
            raise ValueError(
                f"draft_replica {self.draft_replica} out of range for "
                f"{self.n_replicas} replicas")
        self.draft_params = jax.tree_util.tree_map(
            lambda x: x[self.draft_replica], params)
        self.draft_cache = init_cache(cfg, n_slots, cache_len)
        self._propose = jax.jit(make_draft_propose(cfg, k))
        self._verify = jax.jit(make_robust_verify_step(cfg, ensemble,
                                                       mesh=mesh))
        self._accept = jax.jit(accept_block)

    # -- admission -----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    @staticmethod
    def _spliced(cache, slot: int, slot_cache, replicated: bool):
        """One slot's freshly prefilled cache written into a batched cache.

        Period caches are stacked ``(n_periods, B, ...)``, tail caches
        ``(B, ...)``; with ``replicated`` both carry an extra leading
        replica axis.
        """
        if not replicated:
            per, tail = (lambda fl, on: fl.at[:, slot].set(on[:, 0]),
                         lambda fl, on: fl.at[slot].set(on[0]))
        else:
            per, tail = (lambda fl, on: fl.at[:, :, slot].set(on[:, :, 0]),
                         lambda fl, on: fl.at[:, slot].set(on[:, 0]))
        return {
            "periods": jax.tree_util.tree_map(
                per, cache["periods"], slot_cache["periods"]),
            "tail": jax.tree_util.tree_map(
                tail, cache["tail"], slot_cache["tail"]),
        }

    def _splice_cache(self, slot: int, slot_cache) -> None:
        self.cache = self._spliced(self.cache, slot, slot_cache,
                                   self.ensemble is not None)

    def admit(self, req: Request) -> bool:
        """Admit one request into a free slot (returns False when full).

        Runs the prompt through per-slot prefill and splices the
        resulting cache into the batched cache.  In ensemble mode the
        first token is already robust: the replicas' last-position
        logits are aggregated through the configured rule (statelessly —
        the carried-state contract starts on the decode stream).
        """
        slot = self._free_slot()
        if slot is None:
            return False
        req.generated = []
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        if self.ensemble is None:
            logits, slot_cache = prefill(self.params, self.cfg, tokens,
                                         cache_len=self.cache_len)
            first = int(jnp.argmax(logits[0, -1]))
        else:
            agg_logits, slot_cache, _ = self._ens_prefill(self.params, tokens)
            first = int(jnp.argmax(agg_logits[0]))
        self._splice_cache(slot, slot_cache)
        if self.ensemble is not None:
            # a reused slot must not inherit the previous occupant's
            # sliding-window / momentum aggregation history
            from repro.dist.serve_robust import reset_slot_state
            self.agg_state = reset_slot_state(self.agg_state, slot)
        if self.spec_k:
            from repro.serving.speculative import draft_cache_view
            self.draft_cache = self._spliced(
                self.draft_cache, slot,
                draft_cache_view(slot_cache, self.draft_replica),
                replicated=False)
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot] = first
        req.generated.append(first)
        return True

    def submit(self, req: Request) -> None:
        """Queue a request for admission at the next :meth:`step`.

        The engine owns the scheduling: queued requests enter freed slots
        mid-stream (continuous batching) without the caller tracking slot
        occupancy.
        """
        self.pending.append(req)

    def _admit_pending(self) -> None:
        while self.pending and self._free_slot() is not None:
            self.admit(self.pending.pop(0))

    # -- one decode step across all slots -------------------------------------

    def step(self) -> None:
        """Admit queued requests into free slots, then decode the batch.

        Per-token mode decodes one token for every active slot; ensemble
        mode additionally threads ``self.agg_state`` through the robust
        step so stateful rules accumulate their history across tokens;
        speculative mode emits 1..k tokens per slot (draft + batched
        robust verify + acceptance).  A no-op when nothing is active or
        queued.
        """
        self._admit_pending()
        if not any(r is not None for r in self.active):
            return
        if self.spec_k:
            self._step_speculative()
            return
        tokens = jnp.asarray(self.last_token)[:, None]
        # per-slot positions: each sequence ropes/writes at its own index
        pos = jnp.asarray(self.positions, jnp.int32)
        if self.ensemble is None:
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, pos)
            step_logits = logits[:, 0]
        else:
            step_logits, self.cache, _res, self.agg_state = self._decode(
                self.params, self.cache, tokens, pos, self.agg_state)
        nxt = np.asarray(jnp.argmax(step_logits, axis=-1), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.last_token[i] = nxt[i]
            req.generated.append(int(nxt[i]))
            self.positions[i] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None

    def _step_speculative(self) -> None:
        """One speculative engine step: draft k-1, verify k, emit 1..k.

        The draft replica proposes a block per slot; one jit'd verify
        pass scores every position on every replica and aggregates
        robustly; :func:`repro.serving.speculative.accept_block` turns
        the aggregate into per-slot emissions.  Slots accept different
        prefix lengths, so per-slot position counters diverge — exactly
        what the ``pos``-vector decode contract supports.
        """
        tokens = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.positions, jnp.int32)
        block, self.draft_cache = self._propose(
            self.draft_params, self.draft_cache, tokens, pos)
        agg_logits, self.cache, _diag, self.agg_state = self._verify(
            self.params, self.cache, block, pos, self.agg_state)
        emitted, count, _v = self._accept(block, agg_logits)
        emitted = np.asarray(emitted, np.int32)
        count = np.asarray(count, np.int32)
        self.accept_counts.append(count.copy())
        for i, req in enumerate(self.active):
            if req is None:
                continue
            c = min(int(count[i]), req.max_new_tokens - len(req.generated))
            req.generated.extend(int(t) for t in emitted[i, :c])
            self.positions[i] += c
            self.last_token[i] = int(emitted[i, c - 1])
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None

    def telemetry(self) -> Dict:
        """Drain the engine's aggregation forensics to host (numpy).

        Returns the :func:`repro.obs.buffer.drain` report of the carried
        ``AggState``'s metrics ring — empty when the ensemble spec does
        not set ``telemetry=True`` — extended with the speculative
        acceptance record: ``accept_counts`` is the ``(steps, n_slots)``
        per-step accepted-prefix-length history and ``accept_mean`` its
        scalar mean (0.0 before any speculative step ran).
        """
        from repro.obs.buffer import drain
        obs = self.agg_state.obs if self.agg_state is not None else ()
        report = drain(obs)
        counts = (np.stack(self.accept_counts)
                  if self.accept_counts else np.zeros((0, self.n_slots),
                                                      np.int32))
        report["accept_counts"] = counts
        report["accept_mean"] = float(counts.mean()) if counts.size else 0.0
        return report

    def run(self, requests: List[Request], max_steps: int = 1000
            ) -> Dict[int, List[int]]:
        """Serve a list of requests to completion (continuous batching).

        Queues everything via :meth:`submit`, steps the batch (each step
        drains the queue into freed slots before decoding) until
        everything is done or ``max_steps`` is hit, and returns
        ``{rid: generated tokens}``.
        """
        for req in requests:
            self.submit(req)
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            if not self.pending and not any(self.active):
                break
            self.step()
            for req in requests:
                if req.done and req.rid not in results:
                    results[req.rid] = req.generated
        for req in requests:
            results.setdefault(req.rid, req.generated or [])
        return results
