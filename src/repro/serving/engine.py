"""Batched serving engine: fixed-slot continuous batching over the decode
path, with an optional Byzantine-resilient ensemble mode.

Slots hold independent sequences; each engine step decodes one token for
every active slot (a single jit'd ``decode_step`` on the full batch).  New
requests are admitted into free slots via per-slot prefill.  This is the
"serve a small model with batched requests" driver of deliverable (b) and
exercises caches/positions exactly as the decode dry-run shapes do.

Each slot carries its own position counter (mixed-length batching ropes
and cache-writes per slot).  Simplifications vs a production scheduler: no
paged KV; prefill runs at admission time on the slot's sub-batch; greedy
sampling.

**Ensemble mode** (``ensemble=AggSpec(...)``): ``params`` is a
replica-stacked pytree (leading ``(n_replicas,)`` axis on every leaf, see
``repro.dist.serve_robust``), caches are kept per replica, and every
decode step aggregates the ``(n_replicas, n_slots, vocab)`` logits stack
through the ``repro.agg`` registry before sampling — Krum/Bulyan reject a
poisoned replica's distribution; stateful rules thread an ``AggState``
across tokens via ``self.agg_state``.  See docs/serving.md for the
architecture and the AggState-across-tokens contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus generation bookkeeping.

    ``generated`` accumulates sampled token ids (filled by the engine);
    ``done`` flips when ``max_new_tokens`` have been produced.
    """

    rid: int
    prompt: np.ndarray           # (S0,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    """Fixed-slot continuous-batching engine (optionally ensemble-robust).

    Plain mode: ``params`` is one parameter pytree and each step is one
    jit'd ``decode_step`` over all slots.  Ensemble mode (``ensemble=``
    an ``repro.agg.AggSpec``): ``params`` is a replica-stacked pytree (or
    a list of per-replica pytrees, stacked on entry), each step decodes
    every replica and aggregates the logits stack through
    ``spec.gar`` before greedy sampling; ``self.agg_state`` carries the
    ``AggState`` of stateful rules across tokens.

    Host-side counters (``positions``, ``last_token``) are int32 — the
    dtype the jit'd steps consume — so no implicit int64 promotion
    happens at the host/device boundary.
    """

    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 cache_len: int = 512, sampler: str = "greedy",
                 ensemble=None, mesh=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.ensemble = ensemble
        self.positions = np.zeros((n_slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.last_token = np.zeros((n_slots,), np.int32)
        self.sampler = sampler
        self.agg_state = None
        if ensemble is None:
            self.params = params
            self.cache = init_cache(cfg, n_slots, cache_len)
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
            return
        # -- ensemble mode ----------------------------------------------------
        from repro.dist.serve_robust import (init_ensemble_state,
                                             make_robust_prefill_step,
                                             make_robust_serve_step,
                                             replicate_cache,
                                             stack_replicas)
        if isinstance(params, (list, tuple)):
            params = stack_replicas(params)
        self.params = params
        self.n_replicas = jax.tree_util.tree_leaves(params)[0].shape[0]
        self.cache = replicate_cache(init_cache(cfg, n_slots, cache_len),
                                     self.n_replicas)
        self.agg_state = init_ensemble_state(
            ensemble, self.n_replicas, n_slots, cfg.vocab_size)
        self._decode = jax.jit(
            make_robust_serve_step(cfg, ensemble, mesh=mesh))
        self._ens_prefill = make_robust_prefill_step(
            cfg, ensemble, cache_len=cache_len, mesh=mesh)

    # -- admission -----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _splice_cache(self, slot: int, slot_cache) -> None:
        """Write one slot's freshly prefilled cache into the batched cache.

        Period caches are stacked ``(n_periods, B, ...)``, tail caches
        ``(B, ...)``; in ensemble mode both carry an extra leading
        replica axis.
        """
        if self.ensemble is None:
            per, tail = (lambda fl, on: fl.at[:, slot].set(on[:, 0]),
                         lambda fl, on: fl.at[slot].set(on[0]))
        else:
            per, tail = (lambda fl, on: fl.at[:, :, slot].set(on[:, :, 0]),
                         lambda fl, on: fl.at[:, slot].set(on[:, 0]))
        self.cache = {
            "periods": jax.tree_util.tree_map(
                per, self.cache["periods"], slot_cache["periods"]),
            "tail": jax.tree_util.tree_map(
                tail, self.cache["tail"], slot_cache["tail"]),
        }

    def admit(self, req: Request) -> bool:
        """Admit one request into a free slot (returns False when full).

        Runs the prompt through per-slot prefill and splices the
        resulting cache into the batched cache.  In ensemble mode the
        first token is already robust: the replicas' last-position
        logits are aggregated through the configured rule (statelessly —
        the carried-state contract starts on the decode stream).
        """
        slot = self._free_slot()
        if slot is None:
            return False
        req.generated = []
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        if self.ensemble is None:
            logits, slot_cache = prefill(self.params, self.cfg, tokens,
                                         cache_len=self.cache_len)
            first = int(jnp.argmax(logits[0, -1]))
        else:
            agg_logits, slot_cache, _ = self._ens_prefill(self.params, tokens)
            first = int(jnp.argmax(agg_logits[0]))
        self._splice_cache(slot, slot_cache)
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot] = first
        req.generated.append(first)
        return True

    # -- one decode step across all slots -------------------------------------

    def step(self) -> None:
        """Decode one token for every active slot (no-op when idle).

        Ensemble mode additionally threads ``self.agg_state`` through the
        robust step so stateful rules accumulate their history across
        tokens.
        """
        if not any(r is not None for r in self.active):
            return
        tokens = jnp.asarray(self.last_token)[:, None]
        # per-slot positions: each sequence ropes/writes at its own index
        pos = jnp.asarray(self.positions, jnp.int32)
        if self.ensemble is None:
            logits, self.cache = self._decode(self.params, self.cache,
                                              tokens, pos)
            step_logits = logits[:, 0]
        else:
            step_logits, self.cache, _res, self.agg_state = self._decode(
                self.params, self.cache, tokens, pos, self.agg_state)
        nxt = np.asarray(jnp.argmax(step_logits, axis=-1), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.last_token[i] = nxt[i]
            req.generated.append(int(nxt[i]))
            self.positions[i] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None

    def run(self, requests: List[Request], max_steps: int = 1000
            ) -> Dict[int, List[int]]:
        """Serve a list of requests to completion (continuous batching).

        Admits pending requests whenever slots free up, steps the batch
        until everything is done or ``max_steps`` is hit, and returns
        ``{rid: generated tokens}``.
        """
        pending = list(requests)
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            while pending and self._free_slot() is not None:
                self.admit(pending.pop(0))
            if not pending and not any(self.active):
                break
            self.step()
            for req in requests:
                if req.done and req.rid not in results:
                    results[req.rid] = req.generated
        for req in requests:
            results.setdefault(req.rid, req.generated or [])
        return results
