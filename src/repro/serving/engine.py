"""Batched serving engine: fixed-slot continuous batching over the decode
path.

Slots hold independent sequences; each engine step decodes one token for
every active slot (a single jit'd ``decode_step`` on the full batch).  New
requests are admitted into free slots via per-slot prefill.  This is the
"serve a small model with batched requests" driver of deliverable (b) and
exercises caches/positions exactly as the decode dry-run shapes do.

Each slot carries its own position counter (mixed-length batching ropes
and cache-writes per slot).  Simplifications vs a production scheduler: no
paged KV; prefill runs at admission time on the slot's sub-batch; greedy
sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S0,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, n_slots: int = 4,
                 cache_len: int = 512, sampler: str = "greedy"):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.cache = init_cache(cfg, n_slots, cache_len)
        self.positions = np.zeros((n_slots,), np.int64)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.last_token = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
        self.sampler = sampler

    # -- admission -----------------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        req.generated = []
        # per-slot prefill: run the prompt through the model, splice the
        # resulting cache into this slot of the batched cache
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, slot_cache = prefill(self.params, self.cfg, tokens,
                                     cache_len=self.cache_len)
        # period caches are stacked (n_periods, B, ...), tail caches (B, ...)
        self.cache = {
            "periods": jax.tree_util.tree_map(
                lambda fl, on: fl.at[:, slot].set(on[:, 0]),
                self.cache["periods"], slot_cache["periods"]),
            "tail": jax.tree_util.tree_map(
                lambda fl, on: fl.at[slot].set(on[0]),
                self.cache["tail"], slot_cache["tail"]),
        }
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot] = int(jnp.argmax(logits[0, -1]))
        req.generated.append(int(self.last_token[slot]))
        return True

    # -- one decode step across all slots -------------------------------------

    def step(self) -> None:
        if not any(r is not None for r in self.active):
            return
        tokens = jnp.asarray(self.last_token)[:, None]
        # per-slot positions: each sequence ropes/writes at its own index
        logits, self.cache = self._decode(
            self.params, self.cache, tokens,
            jnp.asarray(self.positions, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.last_token[i] = nxt[i]
            req.generated.append(int(nxt[i]))
            self.positions[i] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None

    def run(self, requests: List[Request], max_steps: int = 1000
            ) -> Dict[int, List[int]]:
        pending = list(requests)
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            while pending and self._free_slot() is not None:
                self.admit(pending.pop(0))
            if not pending and not any(self.active):
                break
            self.step()
            for req in requests:
                if req.done and req.rid not in results:
                    results[req.rid] = req.generated
        for req in requests:
            results.setdefault(req.rid, req.generated or [])
        return results
