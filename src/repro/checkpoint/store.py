"""Pytree checkpointing: npz leaves + json manifest.

Layout of ``<path>/``:
  manifest.json  — key paths, shapes, dtypes, step, metadata
  arrays.npz     — leaves keyed by their flattened path

Restores to host numpy; callers re-shard via jax.device_put with their
mesh's shardings (restore is layout-agnostic by design — a checkpoint
written on one mesh can be loaded onto another).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    metadata: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays, keys = {}, []
    for i, (kpath, leaf) in enumerate(flat):
        key = f"{i:05d}:{_path_str(kpath)}"
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == np.dtype("bfloat16"):
            arrays[key] = arr.view(np.uint16)
            keys.append({"key": key, "dtype": "bfloat16",
                         "shape": list(arr.shape)})
        else:
            arrays[key] = arr
            keys.append({"key": key, "dtype": str(arr.dtype),
                         "shape": list(arr.shape)})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": keys, "metadata": metadata or {}}
    with open(os.path.join(path, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    # treedef is reconstructed from an example tree at load; we also store
    # the key paths so mismatches are detected loudly.


def load_checkpoint(path: str, example_tree: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``example_tree`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, example tree "
            f"has {len(flat)}")
    leaves = []
    for (kpath, leaf), meta in zip(flat, manifest["leaves"]):
        want = _path_str(kpath)
        got = meta["key"].split(":", 1)[1]
        if want != got:
            raise ValueError(f"leaf path mismatch: {want} vs {got}")
        arr = data[meta["key"]]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch at {want}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
