"""Deterministic synthetic data pipelines.

The container is offline, so the paper's MNIST/CIFAR-10 are replaced by
*look-alike* tasks with identical shapes and class counts: gaussian
mixtures with fixed per-class means (learnable by the paper's exact models,
separable enough that the accuracy dynamics in Figs. 2-6 reproduce
qualitatively).  The LM stream is a sharp-transition Markov chain — a task
a transformer reduces loss on within a few hundred steps.

Everything is a pure function of (seed, step): workers/hosts can generate
their shards independently and reproducibly (no data files, no I/O).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# classification look-alikes (paper's tasks)
# ---------------------------------------------------------------------------

def _class_means(dim: int, n_classes: int, seed: int) -> np.ndarray:
    """Sparse [0, 1] per-class prototypes, MNIST-like magnitudes: each class
    lights up ~15% of the pixels (norm ~ 10, like a real digit)."""
    rng = np.random.default_rng(seed)
    proto = rng.uniform(0.5, 1.0, (n_classes, dim))
    mask = rng.random((n_classes, dim)) < 0.15
    return (proto * mask).astype(np.float32)


def mnist_like(batch: int, step: int, *, seed: int = 0, noise: float = 0.2,
               task_seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(B, 784) float32 in [0, 1], labels (B,) int32, 10 classes.

    ``task_seed`` fixes the class prototypes (the task itself); ``seed``
    only affects sampling, so train/eval streams with different seeds
    still share one task."""
    means = _class_means(784, 10, task_seed)
    rng = np.random.default_rng((seed, step, 1))
    labels = rng.integers(0, 10, size=batch)
    x = means[labels] + noise * rng.standard_normal((batch, 784))
    return np.clip(x, 0.0, 1.0).astype(np.float32), labels.astype(np.int32)


def cifar_like(batch: int, step: int, *, seed: int = 0, noise: float = 0.25,
               task_seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(B, 32, 32, 3) float32 in [0, 1], labels (B,) int32, 10 classes."""
    means = _class_means(32 * 32 * 3, 10, task_seed + 7)
    rng = np.random.default_rng((seed, step, 2))
    labels = rng.integers(0, 10, size=batch)
    x = means[labels] + noise * rng.standard_normal((batch, 32 * 32 * 3))
    return (np.clip(x, 0.0, 1.0).reshape(batch, 32, 32, 3).astype(np.float32),
            labels.astype(np.int32))


# ---------------------------------------------------------------------------
# LM stream
# ---------------------------------------------------------------------------

def _transition_table(vocab: int, seed: int, branch: int = 4) -> np.ndarray:
    """Each token has ``branch`` likely successors: (vocab, branch) int32."""
    rng = np.random.default_rng(seed + 13)
    return rng.integers(0, vocab, size=(vocab, branch)).astype(np.int32)


def lm_batches(vocab: int, batch: int, seq: int, step: int, *,
               seed: int = 0, branch: int = 4, noise_p: float = 0.05
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Markov-chain token stream -> (tokens (B, S), labels (B, S)) where
    labels are next tokens.  Entropy ~= log(branch) + noise, so a model
    that learns the table reaches loss ~ log(branch)."""
    table = _transition_table(vocab, seed, branch)
    rng = np.random.default_rng((seed, step, 3))
    toks = np.empty((batch, seq + 1), np.int64)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    choices = rng.integers(0, branch, size=(batch, seq))
    noise = rng.random((batch, seq)) < noise_p
    rand_tok = rng.integers(0, vocab, size=(batch, seq))
    for t in range(seq):
        nxt = table[toks[:, t], choices[:, t]]
        toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
    return (toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32))


# ---------------------------------------------------------------------------
# worker-sharded batcher for Byzantine training
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ByzantineBatcher:
    """Yields per-honest-worker mini-batches: honest workers draw i.i.d.
    samples (paper §2.1); Byzantine workers need no data (the adversary
    fabricates gradients)."""

    kind: str                    # mnist | cifar | lm
    n_honest: int
    per_worker: int
    seq: int = 0
    vocab: int = 0
    seed: int = 0
    noise: float = 0.2           # class-overlap knob for mnist/cifar

    def batch(self, step: int):
        xs, ys = [], []
        for w in range(self.n_honest):
            s = step * self.n_honest + w
            if self.kind == "mnist":
                x, y = mnist_like(self.per_worker, s, seed=self.seed,
                                  noise=self.noise)
            elif self.kind == "cifar":
                x, y = cifar_like(self.per_worker, s, seed=self.seed,
                                  noise=self.noise)
            elif self.kind == "lm":
                x, y = lm_batches(self.vocab, self.per_worker, self.seq, s,
                                  seed=self.seed)
            else:
                raise KeyError(self.kind)
            xs.append(x)
            ys.append(y)
        return np.stack(xs), np.stack(ys)
