from repro.data.synthetic import (ByzantineBatcher, cifar_like, lm_batches,
                                  mnist_like)

__all__ = ["ByzantineBatcher", "cifar_like", "lm_batches", "mnist_like"]
