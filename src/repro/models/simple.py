"""The paper's own evaluation models (§5.1).

MNIST: fully-connected 784 -> 100 -> 10 (d ~ 8e4 parameters).
CIFAR-10: conv(3x3,16) -> maxpool(3x3) -> conv(4x4,64) -> maxpool(4x4)
          -> fc 384 -> fc 192 -> softmax (d ~ 1e6 parameters).

Both use ReLU hidden activations, softmax output, max cross-entropy loss,
L2 regularization 1e-4, Xavier init — exactly as §5.1 specifies.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

L2_REG = 1e-4


def _xavier(key, shape):
    fan_in, fan_out = shape[-2] * (shape[0] * shape[1] if len(shape) == 4
                                   else 1), shape[-1]
    if len(shape) == 4:
        fan_in = shape[0] * shape[1] * shape[2]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, minval=-lim, maxval=lim)


# -- MNIST MLP ----------------------------------------------------------------

def init_mnist_mlp(key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": _xavier(k1, (784, 100)), "b1": jnp.zeros((100,)),
        "w2": _xavier(k2, (100, 10)), "b2": jnp.zeros((10,)),
    }


def mnist_mlp_forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, 784) -> logits (B, 10)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


# -- CIFAR CNN ----------------------------------------------------------------

def init_cifar_cnn(key) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "c1": _xavier(ks[0], (3, 3, 3, 16)), "cb1": jnp.zeros((16,)),
        "c2": _xavier(ks[1], (4, 4, 16, 64)), "cb2": jnp.zeros((64,)),
        # 32 -> conv(s1) 32 -> pool3 s2 -> 15 -> conv 15 -> pool4 s3 -> 4
        "w1": _xavier(ks[2], (4 * 4 * 64, 384)), "b1": jnp.zeros((384,)),
        "w2": _xavier(ks[3], (384, 192)), "b2": jnp.zeros((192,)),
        "w3": _xavier(jax.random.fold_in(ks[3], 1), (192, 10)),
        "b3": jnp.zeros((10,)),
    }


def cifar_cnn_forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, 32, 32, 3) -> logits (B, 10)."""
    h = jax.lax.conv_general_dilated(
        x, params["c1"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["cb1"]
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                              (1, 3, 3, 1), (1, 2, 2, 1), "VALID")
    h = jax.lax.conv_general_dilated(
        h, params["c2"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["cb2"]
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                              (1, 4, 4, 1), (1, 3, 3, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def classification_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                        params: dict) -> jnp.ndarray:
    """Cross entropy + L2 (paper §5.1)."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    l2 = sum(jnp.sum(w * w) for w in jax.tree_util.tree_leaves(params))
    return nll + L2_REG * l2


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
