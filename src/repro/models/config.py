"""Model configuration.

One ``ModelConfig`` describes every assigned architecture.  Heterogeneous
layer stacks (hybrid/local-global/cross-attn interleaves) are expressed as a
repeating ``layer_pattern`` of slot descriptors; the model scans over full
periods (params stacked on a leading period axis) and unrolls any remainder
("tail") layers.  Slot descriptors:

  attn      full causal self-attention
  swa       sliding-window causal self-attention (cfg.window)
  chunked   chunked-local causal self-attention (cfg.chunk, llama4 iRoPE)
  attn_nope full attention without RoPE (llama4 global layers)
  mamba     Mamba-2 SSD mixer (attention-free)
  xattn     cross-attention to encoder/vision states (+ self-attention)
  bidir     bidirectional self-attention (encoder)

Each slot is followed by its FFN, which is MoE on layers where
``layer_idx % moe_every == moe_offset`` (when ``moe_experts > 0``),
dense otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # ffn
    ffn_act: str = "swiglu"        # swiglu | geglu | gelu
    qkv_bias: bool = False

    # layer layout
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                # swa window
    chunk: int = 0                 # chunked-attention span

    # moe
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1
    moe_offset: int = 0
    moe_shared: int = 0            # shared (always-on) experts, llama4
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"       # einsum (GShard baseline) | scatter

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    # enc-dec / cross-attn stubs
    encoder_layers: int = 0
    encoder_seq: int = 0           # whisper: 1500 stubbed frame embeddings
    vision_seq: int = 0            # vlm: stubbed patch embeddings

    # misc
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    param_dtype: str = "float32"   # bf16 for the very large archs
    logit_softcap: float = 0.0
    #: unroll the scan-over-periods (analysis-grade dry-runs: XLA cost
    #: analysis and HLO collective parsing see while bodies once, so the
    #: rolled form undercounts per-step work by ~n_periods)
    unroll_scan: bool = False
    #: "batch": constrain attention q/k/v/o to batch-sharding over the
    #: `model` axis (head counts rarely divide a 16-way axis; without this
    #: XLA splits head_dim and all-reduces partial score tensors — §Perf)
    attn_shard: str = "none"
    #: dtype of the unembedding matmul; "bfloat16" halves logits HBM
    #: traffic on huge-vocab models (gemma3: 262k vocab — §Perf).  The
    #: loss's logsumexp stays fp32 either way.
    logits_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_tail(self) -> int:
        return self.n_layers - self.n_periods * self.period

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def slot(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % self.period]

    def is_moe_layer(self, layer_idx: int) -> bool:
        return (self.moe_experts > 0
                and layer_idx % self.moe_every == self.moe_offset)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dff, hd = self.d_model, self.d_ff, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        n_ffn_mats = 3 if self.ffn_act in ("swiglu", "geglu") else 2
        for i in range(self.n_layers):
            slot = self.slot(i)
            if slot == "mamba":
                d_in = self.ssm_expand * d
                h = self.ssm_heads
                total += d * (2 * d_in + 2 * self.ssm_state + h)  # in_proj
                total += self.ssm_conv * (d_in + 2 * self.ssm_state)
                total += 2 * h + d_in                     # A_log, D, dt_bias? norm
                total += d_in * d                         # out_proj
                total += d                                # pre-norm
            else:
                total += d * hd * (nq + 2 * nkv) + hd * nq * d  # qkv + o
                if self.qkv_bias:
                    total += hd * (nq + 2 * nkv)
                total += d                                # pre-norm
                if slot == "xattn":                       # extra cross-attn
                    total += d * hd * (nq + 2 * nkv) + hd * nq * d + d
            if dff > 0:  # every slot (incl. mamba in hybrids) carries a FFN
                if self.is_moe_layer(i):
                    per_e = n_ffn_mats * d * dff
                    total += (self.moe_experts + self.moe_shared) * per_e
                    total += d * self.moe_experts         # router
                else:
                    total += n_ffn_mats * d * dff
                total += d                                # ffn pre-norm
        total += d                                        # final norm
        # encoder stack (whisper)
        for _ in range(self.encoder_layers):
            total += d * hd * (nq + 2 * nkv) + hd * nq * d + d
            total += 2 * d * dff + d                      # gelu mlp
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.moe_experts == 0:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        n_ffn_mats = 3 if self.ffn_act in ("swiglu", "geglu") else 2
        per_e = n_ffn_mats * d * dff
        inactive = 0
        for i in range(self.n_layers):
            if dff > 0 and self.is_moe_layer(i):
                inactive += (self.moe_experts - self.moe_top_k) * per_e
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned input-shape row."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
