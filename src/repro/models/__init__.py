"""Model stack: configs, transformer assembly, serving path, simple models."""
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.transformer import forward, init_model
from repro.models.decode import (decode_step, init_cache, prefill,
                                 verify_step, verify_supported)

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "decode_step",
           "forward", "init_cache", "init_model", "prefill",
           "verify_step", "verify_supported"]
