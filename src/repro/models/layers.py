"""Primitive layers: norms, linear, embedding, FFN (dense + gated variants).

Pure-function style: ``init_*`` builds a param dict, ``apply`` is a plain
function.  No framework dependency — params are nested dicts of jnp arrays,
which keeps pjit sharding rules and checkpointing trivial.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def he_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan)).astype(dtype)


# -- rmsnorm -----------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- linear ------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": he_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# -- embedding ---------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].T.astype(x.dtype)


# -- dense FFN ---------------------------------------------------------------

def init_ffn(key, d: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {"wi": he_init(k1, (d, d_ff), dtype),
                "wg": he_init(k2, (d, d_ff), dtype),
                "wo": he_init(k3, (d_ff, d), dtype)}
    return {"wi": he_init(k1, (d, d_ff), dtype),
            "wo": he_init(k3, (d_ff, d), dtype)}


def ffn(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"], approximate=True)
    return h @ p["wo"]
