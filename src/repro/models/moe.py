"""Mixture-of-Experts FFN with capacity-based (GShard/Switch-style) dispatch.

Dense one-hot dispatch/combine einsums — the TPU-idiomatic formulation:
tokens are routed to per-expert capacity buffers, experts run as one batched
(stacked) matmul, results are combined with the gate weights.  The expert
axis is the natural target for expert-parallel sharding over the `model`
mesh axis (see repro.dist.sharding).  Tokens overflowing an expert's
capacity are dropped (their FFN output is zero; the residual path carries
them), matching Switch Transformer semantics.

Returns a Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

#: process-wide toggle (set by the launcher): when True, expert weights are
#: sharding-constrained to tensor-parallel-only specs at their use site.
#: With FSDP ("data") storage sharding on a *contraction* dim, XLA's SPMD
#: partitioner otherwise computes every worker's expert hiddens on every
#: data shard and all-reduces them — redundant compute plus the dominant
#: collective (measured on mixtral train_4k, §Perf iter 3).  The constraint
#: turns that into one small per-layer weight all-gather instead.
EXPERT_WEIGHT_GATHER: bool = False


def _gathered_experts(experts: dict) -> dict:
    if not EXPERT_WEIGHT_GATHER:
        return experts
    from jax.sharding import PartitionSpec as P
    try:
        out = {}
        for name, w in experts.items():
            if name == "wo":                      # (E, d_ff, d): row-parallel
                spec = P(None, "model", None)
            else:                                 # wi/wg (E, d, d_ff): column
                spec = P(None, None, "model")
            out[name] = jax.lax.with_sharding_constraint(w, spec)
        return out
    except Exception:
        return experts


def init_moe(key, d: int, d_ff: int, n_experts: int, n_shared: int,
             act: str, dtype) -> dict:
    keys = jax.random.split(key, 3)
    n_mats = 3 if act in ("swiglu", "geglu") else 2
    ek = jax.random.split(keys[0], n_experts)
    experts = jax.vmap(lambda k: layers.init_ffn(k, d, d_ff, act, dtype))(ek)
    p = {"router": layers.he_init(keys[1], (d, n_experts), jnp.float32),
         "experts": experts}
    if n_shared > 0:
        p["shared"] = layers.init_ffn(keys[2], d, d_ff * n_shared, act, dtype)
    return p


def moe_ffn(p: dict, x: jnp.ndarray, *, top_k: int, act: str,
            capacity_factor: float = 1.25, impl: str = "einsum"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out: (B, S, D), aux_loss: scalar).

    impl="einsum": GShard-style dense one-hot dispatch/combine — simple,
    but materializes (T, E, C) tensors whose collectives dominate at scale
    (measured in EXPERIMENTS.md §Perf).
    impl="scatter": scatter/gather dispatch — same routing semantics
    (identical positions/drops), never materializes (T, E, C).
    """
    if impl == "scatter":
        return _moe_ffn_scatter(p, x, top_k=top_k, act=act,
                                capacity_factor=capacity_factor)
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])        # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(gates, top_k)      # (T, k)
    # renormalize the chosen gates (mixtral-style)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(math.ceil(top_k * t / e * capacity_factor)))

    # build (T, E, C) dispatch and combine tensors, one top-k slot at a time
    dispatch = jnp.zeros((t, e, capacity), jnp.bool_)
    combine = jnp.zeros((t, e, capacity), jnp.float32)
    fill = jnp.zeros((e,), jnp.int32)                      # tokens per expert
    for slot in range(top_k):
        idx = gate_idx[:, slot]                            # (T,)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)   # (T, E)
        pos = fill[None, :] + jnp.cumsum(onehot, axis=0) - onehot  # (T, E)
        pos_tok = jnp.sum(pos * onehot, axis=1)            # (T,)
        keep = pos_tok < capacity
        disp = (jax.nn.one_hot(pos_tok, capacity, dtype=jnp.float32)
                [:, None, :] * onehot[:, :, None].astype(jnp.float32))
        disp = disp * keep[:, None, None]
        dispatch = dispatch | (disp > 0)
        combine = combine + disp * gate_vals[:, slot][:, None, None]
        fill = fill + jnp.sum(onehot, axis=0)

    # dispatch tokens to expert buffers: (E, C, D)
    exp_in = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), xt)

    def run_expert(ep, xe):
        return layers.ffn(ep, xe, act)

    exp_out = jax.vmap(run_expert)(p["experts"], exp_in)   # (E, C, D)
    out = jnp.einsum("ecd,tec->td", exp_out.astype(jnp.float32), combine)
    out = out.astype(x.dtype).reshape(b, s, d)

    if "shared" in p:
        out = out + layers.ffn(p["shared"], x, act)

    # Switch load-balance loss: E * sum_e (mean gate_e * mean dispatch_e)
    me = jnp.mean(gates, axis=0)                           # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return out, aux


def _moe_ffn_scatter(p: dict, x: jnp.ndarray, *, top_k: int, act: str,
                     capacity_factor: float = 1.25
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter/gather dispatch: routing-identical to the einsum path (same
    cumsum positions, same capacity drops) but the only O(T * E) tensor is
    the int32 position cumsum; token movement is a scatter-add into the
    (E, C, D) expert buffers and a gather back."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])        # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(gates, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(math.ceil(top_k * t / e * capacity_factor)))

    exp_in = jnp.zeros((e, capacity, d), x.dtype)
    fill = jnp.zeros((e,), jnp.int32)
    slots = []
    for slot in range(top_k):
        idx = gate_idx[:, slot]                            # (T,)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)   # (T, E)
        pos = fill[None, :] + jnp.cumsum(onehot, axis=0) - onehot
        pos_tok = jnp.sum(pos * onehot, axis=1)            # (T,)
        keep = pos_tok < capacity
        pc = jnp.minimum(pos_tok, capacity - 1)
        exp_in = exp_in.at[idx, pc].add(
            jnp.where(keep[:, None], xt, 0).astype(exp_in.dtype))
        slots.append((idx, pc, keep))
        fill = fill + jnp.sum(onehot, axis=0)

    def run_expert(ep, xe):
        return layers.ffn(ep, xe, act)

    exp_out = jax.vmap(run_expert)(_gathered_experts(p["experts"]),
                                   exp_in)   # (E, C, D)

    out = jnp.zeros((t, d), jnp.float32)
    for slot, (idx, pc, keep) in enumerate(slots):
        # gather + weight in the compute dtype (keeps expert cotangents
        # bf16 on bf16 models — §Perf iter 2), accumulate in fp32
        y = exp_out[idx, pc]                               # gather (T, D)
        w = (gate_vals[:, slot] * keep.astype(jnp.float32)).astype(y.dtype)
        out = out + (y * w[:, None]).astype(jnp.float32)
    out = out.astype(x.dtype).reshape(b, s, d)

    if "shared" in p:
        out = out + layers.ffn(p["shared"], x, act)

    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return out, aux
