"""Mamba-2 (SSD — state-space duality) mixer.

Chunked SSD algorithm from the Mamba-2 paper (arXiv:2405.21060, Listing 1),
pure jnp: within a chunk the recurrence is evaluated in its "attention dual"
form (a causally-masked (Q, Q) score matmul — MXU work); across chunks a
short ``lax.scan`` carries the (H, N, P) state.  This is the TPU-friendly
layout: the sequential dependency is only over S/Q chunk steps, everything
inside a chunk is dense matmuls.

Single-token decode uses the exact recurrent form with a constant-size
state — the reason mamba2/jamba run the long_500k shape.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


# ---------------------------------------------------------------------------
# core SSD
# ---------------------------------------------------------------------------

def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int = 128,
                return_final_state: bool = False):
    """x: (b,s,h,p), dt: (b,s,h) (>0), A: (h,) (<0), B/C: (b,s,n).
    Returns y: (b,s,h,p) for the SSM  h' = exp(dt A) h + dt B x ; y = C h
    (optionally also the final state (b,h,n,p) for prefill).

    NOTE when ``return_final_state``: padding a chunk dilutes the final
    state only through dt = 0 entries, which contribute nothing — but the
    padded chunk's decay would corrupt it, so callers must pass s % chunk
    == 0 or we trim the pad contribution by construction (dt = 0 => decay
    1, increment 0: safe).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    da = dtc * A[None, None, None, :]                  # (b,nc,q,h), negative
    seg = jnp.cumsum(da, axis=2)                       # inclusive prefix
    xd = xc * dtc[..., None]                           # dt-weighted input

    # --- intra-chunk (the "attention dual") -------------------------------
    cb = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)         # (b,nc,q,q)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask the exponent BEFORE exp: non-causal entries have positive
    # exponents whose exp overflows, and where(mask, exp, 0) still
    # propagates 0 * inf = NaN through the backward pass
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]
    diff = jnp.where(causal[None, None, :, :, None], diff, -1e30)
    scores = cb[..., None] * jnp.exp(diff)             # (b,nc,l,s,h)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, xd)

    # --- chunk boundary states --------------------------------------------
    seg_end = seg[:, :, -1:, :]                        # (b,nc,1,h)
    decay_to_end = jnp.exp(seg_end - seg)              # (b,nc,q,h)
    states = jnp.einsum("bcsh,bcsn,bcshp->bchnp", decay_to_end, Bc, xd)
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])         # (b,nc,h)

    # --- inter-chunk recurrence -------------------------------------------
    def step(Hprev, inp):
        st, dk = inp                                   # (b,h,n,p), (b,h)
        Hnew = Hprev * dk[:, :, None, None] + st
        return Hnew, Hprev

    H0 = jnp.zeros((b, h, n, p), x.dtype)
    H_final, Hprev = jax.lax.scan(
        step, H0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    Hprev = jnp.moveaxis(Hprev, 0, 1)                  # (b,nc,h,n,p)

    y_inter = jnp.einsum("bclh,bcln,bchnp->bclhp",
                         jnp.exp(seg), Cc, Hprev)
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    if return_final_state:
        return y, H_final
    return y


def ssd_recurrent_step(state: jnp.ndarray, x1: jnp.ndarray, dt1: jnp.ndarray,
                       A: jnp.ndarray, B1: jnp.ndarray, C1: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step.  state: (b,h,n,p); x1: (b,h,p); dt1: (b,h);
    B1/C1: (b,n).  Returns (new_state, y: (b,h,p))."""
    decay = jnp.exp(dt1 * A[None, :])                  # (b,h)
    inc = jnp.einsum("bn,bhp->bhnp", B1, x1 * dt1[..., None])
    new_state = state * decay[:, :, None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", C1, new_state)
    return new_state, y


# ---------------------------------------------------------------------------
# the mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.ssm_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_ch = d_in + 2 * n
    return {
        "in_proj": layers.he_init(k1, (d, 2 * d_in + 2 * n + h), dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": layers.init_rmsnorm(d_in, dtype),
        "out_proj": layers.he_init(k3, (d_in, d), dtype),
    }


def _split_proj(proj, d_in, n, h):
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def mamba_forward(p: dict, x: jnp.ndarray, cfg, chunk: int = 128
                  ) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D), full-sequence (training / prefill)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    n, h, hd = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    proj = x @ p["in_proj"]
    z, xbc, dt = _split_proj(proj, d_in, n, h)

    # causal depthwise conv over (x, B, C) channels
    k = p["conv_w"].shape[0]
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i:i + s] * p["conv_w"][i][None, None, :]
               for i in range(k)) + p["conv_b"]
    conv = jax.nn.silu(conv)

    xs = conv[..., :d_in].reshape(b, s, h, hd)
    B_ = conv[..., d_in:d_in + n]
    C_ = conv[..., d_in + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y = ssd_chunked(xs.astype(jnp.float32), dt, A,
                    B_.astype(jnp.float32), C_.astype(jnp.float32),
                    chunk=chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)

    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"]


def mamba_prefill(p: dict, x: jnp.ndarray, cfg, chunk: int = 128
                  ) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence forward returning (y: (B,S,D), cache) — same math as
    ``mamba_forward`` but also stashes the final SSM state and the last
    ssm_conv - 1 conv inputs for subsequent decode steps."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    n, h, hd = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    proj = x @ p["in_proj"]
    z, xbc, dt = _split_proj(proj, d_in, n, h)

    k = p["conv_w"].shape[0]
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i:i + s] * p["conv_w"][i][None, None, :]
               for i in range(k)) + p["conv_b"]
    conv = jax.nn.silu(conv)

    xs = conv[..., :d_in].reshape(b, s, h, hd)
    B_ = conv[..., d_in:d_in + n]
    C_ = conv[..., d_in + n:]
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, H_final = ssd_chunked(xs.astype(jnp.float32), dt_, A,
                             B_.astype(jnp.float32), C_.astype(jnp.float32),
                             chunk=chunk, return_final_state=True)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]

    # conv history: last k-1 raw xbc inputs (zero-padded when s < k-1)
    hist = jax.lax.dynamic_slice_in_dim(
        jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0))), s, k - 1, axis=1)
    cache = {"conv": hist, "state": H_final}
    return out, cache


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    n, h, hd = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, h, n, hd), jnp.float32),
    }


def mamba_decode_step(p: dict, cache: dict, x1: jnp.ndarray, cfg
                      ) -> Tuple[dict, jnp.ndarray]:
    """x1: (B, 1, D) one token.  Returns (new_cache, y: (B, 1, D))."""
    b, _, d = x1.shape
    d_in = cfg.ssm_expand * d
    n, h, hd = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    proj = x1[:, 0] @ p["in_proj"]                     # (B, ...)
    z, xbc, dt = _split_proj(proj, d_in, n, h)

    hist = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    new_conv = hist[:, 1:]

    xs = conv[..., :d_in].reshape(b, h, hd)
    B_ = conv[..., d_in:d_in + n]
    C_ = conv[..., d_in + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    new_state, y = ssd_recurrent_step(
        cache["state"], xs.astype(jnp.float32), dt, A,
        B_.astype(jnp.float32), C_.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, d_in).astype(x1.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = (y @ p["out_proj"])[:, None, :]
    return {"conv": new_conv, "state": new_state}, out
