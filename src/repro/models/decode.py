"""Serving path: KV/SSM caches, prefill, and single-token decode.

Cache layout mirrors the parameter layout: per-period stacked leaves
(scanned), per-tail-layer unstacked.  Attention slots use a full cache of
``cache_len`` positions; sliding-window / chunked slots use a bounded ring
cache of ``window`` / ``chunk`` positions — this is what makes long_500k
decode feasible for SWA/chunked/SSM architectures (the KV state does not
grow with context).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, moe, ssm
from repro.models.attention import decode_attention, rope, verify_attention
from repro.models.config import ModelConfig
from repro.models.layers import _dtype


def slot_cache_len(cfg: ModelConfig, slot: str, cache_len: int) -> int:
    if slot == "swa" and cfg.window > 0:
        return min(cfg.window, cache_len)
    if slot == "chunked" and cfg.chunk > 0:
        return min(cfg.chunk, cache_len)
    return cache_len


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def _init_slot_cache(cfg: ModelConfig, slot: str, batch: int,
                     cache_len: int, dtype) -> dict:
    hd, hkv = cfg.head_dim, cfg.n_kv_heads
    if slot == "mamba":
        return ssm.init_mamba_cache(cfg, batch, dtype)
    L = slot_cache_len(cfg, slot, cache_len)
    c = {"k": jnp.zeros((batch, L, hkv, hd), dtype),
         "v": jnp.zeros((batch, L, hkv, hd), dtype)}
    if slot == "xattn":
        se = cfg.encoder_seq or cfg.vision_seq
        c["xk"] = jnp.zeros((batch, se, hkv, hd), dtype)
        c["xv"] = jnp.zeros((batch, se, hkv, hd), dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    dtype = _dtype(cfg.param_dtype)
    periods = {}
    for j, slot in enumerate(cfg.layer_pattern):
        one = _init_slot_cache(cfg, slot, batch, cache_len, dtype)
        periods[f"s{j}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_periods,) + x.shape), one)
    tail = {}
    for t in range(cfg.n_tail):
        slot = cfg.slot(cfg.n_periods * cfg.period + t)
        tail[f"t{t}"] = _init_slot_cache(cfg, slot, batch, cache_len, dtype)
    return {"periods": periods, "tail": tail}


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _decode_attn_slot(p, c, x, cfg: ModelConfig, slot: str, pos
                      ) -> Tuple[dict, jnp.ndarray]:
    """``pos``: scalar or (B,) — per-sequence positions, so mixed-length
    continuous batching ropes/writes every slot at its own index."""
    b, _, d = x.shape
    hd = cfg.head_dim
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    h = layers.rmsnorm(p["ln"], x)
    q = (h @ p["attn"]["wq"] + p["attn"].get("bq", 0.0)
         ).reshape(b, 1, cfg.n_heads, hd)
    k = (h @ p["attn"]["wk"] + p["attn"].get("bk", 0.0)
         ).reshape(b, 1, cfg.n_kv_heads, hd)
    v = (h @ p["attn"]["wv"] + p["attn"].get("bv", 0.0)
         ).reshape(b, 1, cfg.n_kv_heads, hd)
    if slot != "attn_nope":
        posv = pos[:, None]  # (B, 1) broadcasts through rope to (B, S=1)
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
    L = c["k"].shape[1]
    ring = slot in ("swa", "chunked")
    idx = (pos % L) if ring else jnp.minimum(pos, L - 1)  # (B,)
    bidx = jnp.arange(b)
    kc = c["k"].at[bidx, idx].set(k[:, 0].astype(c["k"].dtype))
    vc = c["v"].at[bidx, idx].set(v[:, 0].astype(c["v"].dtype))
    valid = jnp.minimum(pos + 1, L)
    o = decode_attention(q, kc, vc, valid_len=valid)
    y = o.reshape(b, 1, cfg.n_heads * hd) @ p["attn"]["wo"]
    newc = dict(c)
    newc["k"], newc["v"] = kc, vc
    return newc, y


def _decode_layer(p, c, x, cfg: ModelConfig, slot: str, pos
                  ) -> Tuple[dict, jnp.ndarray]:
    if slot == "mamba":
        h = layers.rmsnorm(p["ln"], x)
        newc, y = ssm.mamba_decode_step(p["mix"], c, h, cfg)
        x = x + y
    else:
        newc, y = _decode_attn_slot(p, c, x, cfg, slot, pos)
        x = x + y
        if slot == "xattn":
            b = x.shape[0]
            hd = cfg.head_dim
            h = layers.rmsnorm(p["ln_x"], x)
            q = (h @ p["xatt"]["wq"] + p["xatt"].get("bq", 0.0)
                 ).reshape(b, 1, cfg.n_heads, hd)
            o = decode_attention(q, c["xk"], c["xv"])
            x = x + o.reshape(b, 1, cfg.n_heads * hd) @ p["xatt"]["wo"]
    if "ffn" in p:
        x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln_f"], x),
                           cfg.ffn_act)
    elif "moe" in p:
        y, _ = moe.moe_ffn(p["moe"], layers.rmsnorm(p["ln_f"], x),
                           top_k=cfg.moe_top_k, act=cfg.ffn_act,
                           capacity_factor=cfg.capacity_factor,
                           impl=cfg.moe_impl)
        x = x + y
    return newc, x


def decode_step(params, cfg: ModelConfig, cache: dict, token: jnp.ndarray,
                pos) -> Tuple[jnp.ndarray, dict]:
    """token: (B, 1) int32; pos: scalar or (B,) per-sequence positions.
    Returns (logits (B, 1, V), new_cache)."""
    x = layers.embed(params["embed"], token)

    def body(x, xs):
        period_p, period_c = xs
        newc = {}
        for j, slot in enumerate(cfg.layer_pattern):
            newc[f"s{j}"], x = _decode_layer(period_p[f"s{j}"],
                                             period_c[f"s{j}"], x, cfg,
                                             slot, pos)
        return x, newc

    x, new_periods = jax.lax.scan(
        body, x, (params["periods"], cache["periods"]),
        unroll=cfg.unroll_scan)

    new_tail = {}
    for t in range(cfg.n_tail):
        slot = cfg.slot(cfg.n_periods * cfg.period + t)
        new_tail[f"t{t}"], x = _decode_layer(
            params["tail"][f"t{t}"], cache["tail"][f"t{t}"], x, cfg, slot,
            pos)

    x = layers.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["lm_head"], x)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"periods": new_periods, "tail": new_tail}


# ---------------------------------------------------------------------------
# verify step (a causal block of new tokens — the speculative-decoding path)
# ---------------------------------------------------------------------------

_RING_SLOTS = ("swa", "chunked")


def verify_supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Whether ``verify_step`` (and hence speculative decoding) applies.

    The multi-token verify block relies on positional cache rollback: a
    rejected draft suffix leaves garbage cache entries *above* the
    accepted position, which per-query causal masking hides until the
    next block overwrites them.  Two cache families break that invariant:

    * ring caches (``swa`` / ``chunked`` slots) wrap rejected writes onto
      *valid* old window entries, which stay visible;
    * recurrent SSM state (``mamba``) advances destructively — there is
      no positional index to roll back to.

    Args:
      cfg: model configuration to probe.

    Returns:
      ``(ok, reason)`` — ``reason`` names the offending layer slot when
      ``ok`` is False (empty string otherwise).
    """
    slots = set(cfg.layer_pattern)
    slots.update(cfg.slot(cfg.n_periods * cfg.period + t)
                 for t in range(cfg.n_tail))
    for slot in sorted(slots):
        if slot == "mamba":
            return False, ("mamba: recurrent SSM state cannot roll back "
                           "a rejected draft suffix")
        if slot in _RING_SLOTS:
            return False, (f"{slot}: ring cache wraps rejected draft "
                           f"writes onto valid window entries")
    return True, ""


def _verify_attn_slot(p, c, x, cfg: ModelConfig, slot: str, pos
                      ) -> Tuple[dict, jnp.ndarray]:
    """One attention layer over a ``(B, S)`` verify block: token ``j``
    sits at position ``pos + j``.  All S keys are written first, then
    every query attends its own causal prefix (``verify_attention``) —
    so entry ``j``'s output equals the sequential decode that fed the
    same ``j`` tokens, and garbage above the block (rejected drafts of
    earlier rounds) stays masked."""
    b, s, d = x.shape
    hd = cfg.head_dim
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    qpos = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # (B, S)
    h = layers.rmsnorm(p["ln"], x)
    q = (h @ p["attn"]["wq"] + p["attn"].get("bq", 0.0)
         ).reshape(b, s, cfg.n_heads, hd)
    k = (h @ p["attn"]["wk"] + p["attn"].get("bk", 0.0)
         ).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ p["attn"]["wv"] + p["attn"].get("bv", 0.0)
         ).reshape(b, s, cfg.n_kv_heads, hd)
    if slot != "attn_nope":
        q = rope(q, qpos, cfg.rope_theta)
        k = rope(k, qpos, cfg.rope_theta)
    L = c["k"].shape[1]
    if slot in _RING_SLOTS or slot == "mamba":
        raise ValueError(
            f"verify_step does not support {slot!r} slots (ring/SSM "
            f"caches cannot roll back rejected draft tokens)")
    idx = jnp.minimum(qpos, L - 1)  # (B, S)
    bidx = jnp.arange(b)[:, None]
    kc = c["k"].at[bidx, idx].set(k.astype(c["k"].dtype))
    vc = c["v"].at[bidx, idx].set(v.astype(c["v"].dtype))
    q_valid = jnp.minimum(qpos + 1, L)  # per-query causal prefix
    o = verify_attention(q, kc, vc, q_valid=q_valid)
    y = o.reshape(b, s, cfg.n_heads * hd) @ p["attn"]["wo"]
    newc = dict(c)
    newc["k"], newc["v"] = kc, vc
    return newc, y


def _verify_layer(p, c, x, cfg: ModelConfig, slot: str, pos
                  ) -> Tuple[dict, jnp.ndarray]:
    newc, y = _verify_attn_slot(p, c, x, cfg, slot, pos)
    x = x + y
    if slot == "xattn":
        b, s = x.shape[0], x.shape[1]
        hd = cfg.head_dim
        h = layers.rmsnorm(p["ln_x"], x)
        q = (h @ p["xatt"]["wq"] + p["xatt"].get("bq", 0.0)
             ).reshape(b, s, cfg.n_heads, hd)
        o = verify_attention(q, c["xk"], c["xv"])
        x = x + o.reshape(b, s, cfg.n_heads * hd) @ p["xatt"]["wo"]
    if "ffn" in p:
        x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln_f"], x),
                           cfg.ffn_act)
    elif "moe" in p:
        y, _ = moe.moe_ffn(p["moe"], layers.rmsnorm(p["ln_f"], x),
                           top_k=cfg.moe_top_k, act=cfg.ffn_act,
                           capacity_factor=cfg.capacity_factor,
                           impl=cfg.moe_impl)
        x = x + y
    return newc, x


def verify_step(params, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
                pos) -> Tuple[jnp.ndarray, dict]:
    """Decode a causal block of ``S`` tokens in one forward pass.

    The speculative-verify analogue of ``decode_step``: ``tokens[:, j]``
    is consumed at position ``pos + j`` and ``logits[:, j]`` predicts the
    token at position ``pos + j + 1`` — exactly what ``S`` sequential
    ``decode_step`` calls on the same tokens would produce, but with one
    model pass (keys written first, per-query causal masking).  Requires
    full attention caches (``verify_supported``).

    Args:
      params: parameter pytree of one model.
      cfg: model configuration.
      cache: decode-cache pytree (``init_cache`` layout).
      tokens: ``(B, S)`` int32 token block.
      pos: scalar or ``(B,)`` int32 — per-slot position of ``tokens[:, 0]``.

    Returns:
      ``(logits (B, S, V), new_cache)`` — the cache gains the block's
      ``S`` key/value entries per attention layer.
    """
    x = layers.embed(params["embed"], tokens)

    def body(x, xs):
        period_p, period_c = xs
        newc = {}
        for j, slot in enumerate(cfg.layer_pattern):
            newc[f"s{j}"], x = _verify_layer(period_p[f"s{j}"],
                                             period_c[f"s{j}"], x, cfg,
                                             slot, pos)
        return x, newc

    x, new_periods = jax.lax.scan(
        body, x, (params["periods"], cache["periods"]),
        unroll=cfg.unroll_scan)

    new_tail = {}
    for t in range(cfg.n_tail):
        slot = cfg.slot(cfg.n_periods * cfg.period + t)
        new_tail[f"t{t}"], x = _verify_layer(
            params["tail"][f"t{t}"], cache["tail"][f"t{t}"], x, cfg, slot,
            pos)

    x = layers.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["lm_head"], x)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"periods": new_periods, "tail": new_tail}


# ---------------------------------------------------------------------------
# prefill (fills caches; used by the serving engine + consistency tests)
# ---------------------------------------------------------------------------

def _prefill_slot(p, x, cfg: ModelConfig, slot: str, positions, enc_out,
                  cache_len: int, impl: str):
    """Apply one layer full-sequence AND return its filled cache."""
    from repro.models.transformer import (_apply_layer, _self_attention)
    b, s, d = x.shape
    hd = cfg.head_dim
    if slot == "mamba":
        h = layers.rmsnorm(p["ln"], x)
        y, cache = ssm.mamba_prefill(p["mix"], h, cfg)
        x = x + y
        if "ffn" in p:
            x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln_f"], x),
                               cfg.ffn_act)
        elif "moe" in p:
            y2, _ = moe.moe_ffn(p["moe"], layers.rmsnorm(p["ln_f"], x),
                                top_k=cfg.moe_top_k, act=cfg.ffn_act,
                                capacity_factor=cfg.capacity_factor,
                                impl=cfg.moe_impl)
            x = x + y2
        return x, cache
    # attention slots: recompute k/v to stash (cheap vs the attention itself)
    h = layers.rmsnorm(p["ln"], x)
    k = (h @ p["attn"]["wk"] + p["attn"].get("bk", 0.0)
         ).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ p["attn"]["wv"] + p["attn"].get("bv", 0.0)
         ).reshape(b, s, cfg.n_kv_heads, hd)
    if slot != "attn_nope":
        k = rope(k, positions, cfg.rope_theta)
    L = slot_cache_len(cfg, slot, cache_len)
    if s >= L:
        kc, vc = k[:, -L:], v[:, -L:]
    else:
        pad = ((0, 0), (0, L - s), (0, 0), (0, 0))
        kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = {"k": kc, "v": vc}
    if slot == "xattn":
        hx = enc_out
        cache["xk"] = (hx @ p["xatt"]["wk"] + p["xatt"].get("bk", 0.0)
                       ).reshape(b, hx.shape[1], cfg.n_kv_heads, hd)
        cache["xv"] = (hx @ p["xatt"]["wv"] + p["xatt"].get("bv", 0.0)
                       ).reshape(b, hx.shape[1], cfg.n_kv_heads, hd)
    x, _ = _apply_layer(p, x, cfg, slot, 0, positions, enc_out, impl)
    return x, cache


def prefill(params, cfg: ModelConfig, tokens: jnp.ndarray,
            extra: Optional[jnp.ndarray] = None, cache_len: int = 0,
            impl: str = "auto") -> Tuple[jnp.ndarray, dict]:
    """Full-sequence forward that also returns populated decode caches.
    ``cache_len`` defaults to the sequence length."""
    from repro.models.transformer import _run_encoder
    b, s = tokens.shape
    cache_len = cache_len or s
    x = layers.embed(params["embed"], tokens)
    if cfg.arch_type == "audio":
        enc_out = _run_encoder(params, cfg, extra, impl)
    elif cfg.arch_type == "vlm":
        enc_out = extra
    else:
        enc_out = None
    positions = jnp.arange(s)

    def body(x, period_p):
        caches = {}
        for j, slot in enumerate(cfg.layer_pattern):
            x, caches[f"s{j}"] = _prefill_slot(
                period_p[f"s{j}"], x, cfg, slot, positions, enc_out,
                cache_len, impl)
        return x, caches

    x, period_caches = jax.lax.scan(body, x, params["periods"],
                                    unroll=cfg.unroll_scan)
    tail_caches = {}
    for t in range(cfg.n_tail):
        slot = cfg.slot(cfg.n_periods * cfg.period + t)
        x, tail_caches[f"t{t}"] = _prefill_slot(
            params["tail"][f"t{t}"], x, cfg, slot, positions, enc_out,
            cache_len, impl)

    x = layers.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["lm_head"], x)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"periods": period_caches, "tail": tail_caches}
