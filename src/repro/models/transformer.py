"""Model assembly: embeddings + scanned layer periods + decode caches.

The layer stack is grouped into repeating *periods* (cfg.layer_pattern);
parameters for each slot are stacked on a leading ``n_periods`` axis and the
stack is traversed with ``jax.lax.scan`` (small HLO, fast compiles, natural
remat boundary).  Remainder layers ("tail", when n_layers % period != 0) are
unrolled with their own parameters.

Three entry points:
  forward      — full-sequence logits (training / evaluation)
  prefill      — full-sequence logits + populated decode caches
  decode_step  — one token against the caches (serving)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, moe, ssm
from repro.models.attention import (attention, decode_attention, rope)
from repro.models.config import ModelConfig
from repro.models.layers import _dtype


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.he_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": layers.he_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": layers.he_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": layers.he_init(ko, (cfg.n_heads * hd, d), dtype,
                             fan_in=cfg.n_heads * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _init_slot(key, cfg: ModelConfig, slot: str, layer_idx: int,
               dtype, enc: bool = False) -> dict:
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln": layers.init_rmsnorm(d, dtype)}
    if slot == "mamba":
        p["mix"] = ssm.init_mamba(keys[0], cfg, dtype)
    else:
        p["attn"] = _init_attn(keys[0], cfg, dtype)
        if slot == "xattn":
            p["ln_x"] = layers.init_rmsnorm(d, dtype)
            p["xatt"] = _init_attn(keys[1], cfg, dtype)
    if cfg.d_ff > 0:
        p["ln_f"] = layers.init_rmsnorm(d, dtype)
        act = "gelu" if enc else cfg.ffn_act
        if not enc and cfg.is_moe_layer(layer_idx):
            p["moe"] = moe.init_moe(keys[2], d, cfg.d_ff, cfg.moe_experts,
                                    cfg.moe_shared, act, dtype)
        else:
            p["ffn"] = layers.init_ffn(keys[2], d, cfg.d_ff, act, dtype)
    return p


def init_model(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg.param_dtype)
    k_embed, k_per, k_tail, k_enc, k_head = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(k_embed, cfg.vocab_size,
                                       cfg.d_model, dtype),
        "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_linear(
            k_head, cfg.d_model, cfg.vocab_size, dtype)

    period_keys = jax.random.split(k_per, max(cfg.n_periods, 1))
    periods = {}
    for j, slot in enumerate(cfg.layer_pattern):
        def init_one(k, j=j, slot=slot):
            sk = jax.random.fold_in(k, j)
            return _init_slot(sk, cfg, slot, j, dtype)
        periods[f"s{j}"] = jax.vmap(init_one)(period_keys)
    params["periods"] = periods

    tail = {}
    for t in range(cfg.n_tail):
        layer_idx = cfg.n_periods * cfg.period + t
        slot = cfg.slot(layer_idx)
        tail[f"t{t}"] = _init_slot(jax.random.fold_in(k_tail, t), cfg, slot,
                                   layer_idx, dtype)
    params["tail"] = tail

    if cfg.encoder_layers > 0:
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: _init_slot(k, cfg, "bidir", 0, dtype, enc=True)
            )(enc_keys),
            "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# full-sequence forward
# ---------------------------------------------------------------------------

def _attn_constrain(t: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """cfg.attn_shard == "batch": pin (b, s, h, hd) to batch-sharding over
    `model` so score einsums are local (no head_dim splitting).  Under the
    worker vmap (spmd_axis_name="data") the worker dim is inserted
    automatically.  No-op when the batch doesn't divide or outside jit."""
    if cfg.attn_shard != "batch":
        return t
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            t, P("model", *([None] * (t.ndim - 1))))
    except Exception:
        return t


def _self_attention(p, x, cfg: ModelConfig, slot: str, positions,
                    impl: str) -> jnp.ndarray:
    b, s, d = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"] + p.get("bq", 0.0)).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"] + p.get("bk", 0.0)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"] + p.get("bv", 0.0)).reshape(b, s, cfg.n_kv_heads, hd)
    q, k, v = (_attn_constrain(t, cfg) for t in (q, k, v))
    if slot not in ("attn_nope",):
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    kind = {"attn": "attn", "attn_nope": "attn", "swa": "swa",
            "chunked": "chunked", "bidir": "bidir", "xattn": "attn"}[slot]
    o = attention(q, k, v, kind=kind, window=cfg.window, chunk=cfg.chunk,
                  impl=impl)
    o = _attn_constrain(o, cfg)
    return o.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def _cross_attention(p, x, enc_out, cfg: ModelConfig, impl: str
                     ) -> jnp.ndarray:
    b, s, d = x.shape
    hd = cfg.head_dim
    se = enc_out.shape[1]
    q = (x @ p["wq"] + p.get("bq", 0.0)).reshape(b, s, cfg.n_heads, hd)
    k = (enc_out @ p["wk"] + p.get("bk", 0.0)).reshape(
        b, se, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"] + p.get("bv", 0.0)).reshape(
        b, se, cfg.n_kv_heads, hd)
    o = attention(q, k, v, kind="cross", impl=impl)
    return o.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def _apply_layer(p, x, cfg: ModelConfig, slot: str, layer_idx: int,
                 positions, enc_out, impl: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if slot == "mamba":
        x = x + ssm.mamba_forward(p["mix"], layers.rmsnorm(p["ln"], x), cfg)
    else:
        x = x + _self_attention(p["attn"], layers.rmsnorm(p["ln"], x), cfg,
                                slot, positions, impl)
        if slot == "xattn":
            x = x + _cross_attention(p["xatt"],
                                     layers.rmsnorm(p["ln_x"], x),
                                     enc_out, cfg, impl)
    if "ffn" in p:
        act = cfg.ffn_act if "moe" not in p else cfg.ffn_act
        x = x + layers.ffn(p["ffn"], layers.rmsnorm(p["ln_f"], x), cfg.ffn_act)
    elif "moe" in p:
        y, a = moe.moe_ffn(p["moe"], layers.rmsnorm(p["ln_f"], x),
                           top_k=cfg.moe_top_k, act=cfg.ffn_act,
                           capacity_factor=cfg.capacity_factor,
                           impl=cfg.moe_impl)
        x = x + y
        aux = aux + a
    return x, aux


def _run_encoder(params, cfg: ModelConfig, enc_embeds, impl: str
                 ) -> jnp.ndarray:
    positions = jnp.arange(enc_embeds.shape[1])

    def body(x, lp):
        x, _ = _apply_layer(lp, x, cfg, "bidir", 0, positions, None, impl)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), enc_embeds,
                        params["encoder"]["layers"],
                        unroll=cfg.unroll_scan)
    return layers.rmsnorm(params["encoder"]["final_norm"], x)


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            extra: Optional[jnp.ndarray] = None, impl: str = "auto"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) -> (logits (B, S, V), aux_loss scalar).

    ``extra`` carries stubbed modality embeddings: whisper frame embeddings
    or VLM patch embeddings, shape (B, S_enc, d_model)."""
    x = layers.embed(params["embed"], tokens)
    if cfg.arch_type in ("audio",):
        assert extra is not None, "whisper needs encoder frame embeddings"
        enc_out = _run_encoder(params, cfg, extra, impl)
    elif cfg.arch_type == "vlm":
        assert extra is not None, "vlm needs patch embeddings"
        enc_out = extra
    else:
        enc_out = None

    positions = jnp.arange(tokens.shape[1])
    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, period_p):
        x, aux = carry
        for j, slot in enumerate(cfg.layer_pattern):
            x, a = _apply_layer(period_p[f"s{j}"], x, cfg, slot, j,
                                positions, enc_out, impl)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(jax.checkpoint(body), (x, aux0),
                               params["periods"], unroll=cfg.unroll_scan)
    for t in range(cfg.n_tail):
        layer_idx = cfg.n_periods * cfg.period + t
        slot = cfg.slot(layer_idx)
        x, a = jax.checkpoint(
            functools.partial(_apply_layer, cfg=cfg, slot=slot,
                              layer_idx=layer_idx, positions=positions,
                              enc_out=enc_out, impl=impl)
        )(params["tail"][f"t{t}"], x)
        aux = aux + a

    x = layers.rmsnorm(params["final_norm"], x)
    if cfg.logits_dtype == "bfloat16":
        x = x.astype(jnp.bfloat16)
        emb = jax.tree_util.tree_map(lambda w: w.astype(jnp.bfloat16),
                                     params.get("lm_head",
                                                params["embed"]))
    else:
        emb = params.get("lm_head", params["embed"])
    if cfg.tie_embeddings:
        logits = layers.unembed(emb, x)
    else:
        logits = layers.linear(emb, x)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, aux
