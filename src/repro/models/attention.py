"""Attention: GQA/MQA with RoPE, full / sliding-window / chunked-local /
bidirectional / cross variants, a naive einsum path and a blockwise
(flash-style, online-softmax) path, plus single-token decode against a KV
cache.

Shapes: q (B, Sq, Hq, D); k, v (B, Sk, Hkv, D) with Hq = G * Hkv.
Softmax statistics are fp32 regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# -- RoPE ---------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D), positions: (S,) or broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (S, half)
    cos = jnp.cos(ang)[..., None, :]  # (S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


# -- masks --------------------------------------------------------------------

def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, kind: str,
               window: int, chunk: int) -> jnp.ndarray:
    """(Sq, Sk) additive bias: 0 where attendable, NEG_INF elsewhere."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if kind == "bidir":
        ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    elif kind == "cross":
        ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    else:
        ok = kp <= qp  # causal
        if kind == "swa" and window > 0:
            ok &= (qp - kp) < window
        elif kind == "chunked" and chunk > 0:
            ok &= (qp // chunk) == (kp // chunk)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# -- naive path ---------------------------------------------------------------

def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """-> (B, Hkv, G, Sq, Sk) fp32 scores."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                      preferred_element_type=jnp.float32)


def attention_naive(q, k, v, *, kind: str = "attn", window: int = 0,
                    chunk: int = 0, q_offset=0) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scores = _gqa_scores(q, k) / jnp.sqrt(d).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    bias = _mask_bias(q_pos, k_pos, kind, window, chunk)
    scores = scores + bias[None, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)


# -- blockwise (flash-style) path ----------------------------------------------

def attention_blockwise(q, k, v, *, kind: str = "attn", window: int = 0,
                        chunk: int = 0, q_offset=0, block_q: int = 1024,
                        block_k: int = 1024) -> jnp.ndarray:
    """Online-softmax attention, O(block_q * block_k) live scores.

    Outer static loop over q blocks; for causal/local kinds, k blocks that a
    q block can never attend to are *statically skipped* (block-sparsity for
    sliding-window / chunked layouts), cutting both FLOPs and memory traffic.
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    pad_k = (-sk) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k
    scale = 1.0 / float(d) ** 0.5

    k_pos_all = jnp.arange(k.shape[1])
    outs = []
    static_offset = isinstance(q_offset, int)
    for iq in range(nq):
        qb = q[:, iq * block_q:(iq + 1) * block_q]
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)
        # static k-block range for this q block (block-sparse skipping);
        # only valid when q_offset is a static python int
        lo_blk, hi_blk = 0, nk
        if static_offset and kind in ("attn", "swa", "chunked"):
            q_lo = q_offset + iq * block_q
            q_hi = q_offset + (iq + 1) * block_q - 1
            hi_blk = min(nk, (q_hi // block_k) + 1)           # causal
            if kind == "swa" and window > 0:
                lo_blk = max(0, (q_lo - window + 1) // block_k)
            elif kind == "chunked" and chunk > 0:
                lo_blk = max(0, ((q_lo // chunk) * chunk) // block_k)
        m = jnp.full((b, block_q, hkv, g), NEG_INF, jnp.float32)
        l = jnp.zeros((b, block_q, hkv, g), jnp.float32)
        acc = jnp.zeros((b, block_q, hkv, g, d), jnp.float32)

        def kv_step(carry, ik):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ik * block_k, block_k, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ik * block_k, block_k, 1)
            k_pos = ik * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhgd,bkhd->bqhgk",
                           qb.reshape(b, block_q, hkv, g, d), kb,
                           preferred_element_type=jnp.float32) * scale
            bias = _mask_bias(q_pos, k_pos, kind, window, chunk)
            s = s + bias[None, :, None, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        n_blocks = hi_blk - lo_blk
        if n_blocks <= 0:
            outs.append(jnp.zeros((b, block_q, hq, d), q.dtype))
            continue
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m, l, acc), lo_blk + jnp.arange(n_blocks))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(o.reshape(b, block_q, hq, d).astype(q.dtype))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :sq]


def attention(q, k, v, *, kind: str = "attn", window: int = 0,
              chunk: int = 0, q_offset=0, impl: str = "auto") -> jnp.ndarray:
    if impl == "auto":
        impl = "blockwise" if max(q.shape[1], k.shape[1]) > 8192 else "naive"
    fn = attention_blockwise if impl == "blockwise" else attention_naive
    return fn(q, k, v, kind=kind, window=window, chunk=chunk,
              q_offset=q_offset)


# -- decode (single new token against a cache) ---------------------------------

def decode_attention(q1: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray,
                     valid_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q1: (B, 1, Hq, D); caches: (B, S, Hkv, D).  Attends to the whole
    cache (or the first ``valid_len`` entries)."""
    b, _, hq, d = q1.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q1.reshape(b, 1, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    if valid_len is not None:
        mask = jnp.arange(s)[None, :] < valid_len[:, None]  # (B, S)
        scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, d)


# -- verify (a block of new tokens against a cache, causal) ---------------------

def verify_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray,
                     q_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Multi-query decode attention for speculative verify blocks.

    ``q``: (B, Sq, Hq, D) — a block of ``Sq`` new-token queries; caches:
    (B, L, Hkv, D).  Each query ``j`` attends to the first
    ``q_valid[:, j]`` cache entries (per-query causal prefix — the block's
    own keys must already be written into the cache).  ``q_valid=None``
    attends to the whole cache (the cross-attention case).

    At ``Sq = 1`` with ``q_valid = valid_len[:, None]`` this computes
    exactly what :func:`decode_attention` computes — the single-token
    decode step is the degenerate verify block.
    """
    b, sq, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    if q_valid is not None:
        mask = jnp.arange(s)[None, None, :] < q_valid[:, :, None]  # (B,Sq,S)
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, sq, hq, d)
