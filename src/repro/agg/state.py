"""Explicit aggregation state for stateful rules.

Stateless rules (everything the paper benchmarks) carry no state and pay
nothing: the trainer/step builders only thread an :class:`AggState` when
``resolve_rule(gar).stateful`` is True, so the jitted step signature of
stateless runs is unchanged.

The state is a plain pytree (a NamedTuple of arrays / tuples of arrays),
so it jits, shards, and donates like any other carry:

* ``step``     — int32 scalar, number of aggregations absorbed so far;
* ``history``  — the ``buffered-*`` sliding-window buffer: for the dense
  path one ``(W, n, d)`` array, for the tree path a tuple of
  ``(W, n, *dims)`` leaves in the flat order of the gradient tree;
* ``center``   — the momentum-carried center of
  ``centered_clip_momentum``: ``(d,)`` dense, tuple of ``(*dims,)``
  leaves on the tree path.
* ``bus``      — the asynchronous runtime's ``GradientBus``
  (``repro.dist.async_train``): per-worker versioned gradient slots in
  the *same layout as the template* (a bare ``(n, d)`` array dense, the
  gradient pytree itself on the tree path) plus ``(n,)`` int32
  ``versions`` / ``arrival_step`` arrays.  The ``stale-<base>`` rules
  (``repro.agg.staleness``) read staleness as ``step - bus.versions``;
  the async step owns the slot writes.
* ``reputation`` — the ``reputation-<base>`` rules' per-worker fp32
  scores in ``[0, 1]`` (``repro.agg.reputation``), initialized to
  **ones** (everyone fully trusted — uniform reputation reproduces the
  base rule bitwise).  Training states carry ``(n,)``; the serving
  layer allocates per-slot ``(n, batch)`` columns via ``rep_dims`` so
  slot reuse can reset one request's column without touching the rest.
* ``obs`` — the ``obs-<base>`` telemetry rules' fixed-size
  ``MetricsBuffer`` forensics ring (``repro.obs.buffer``), one
  ``AggDiagnostics`` row pushed per aggregation call and drained on
  host between steps.  The ring never feeds back into the data path.

Unused fields stay ``()`` (an empty pytree), so a rule only allocates
the buffers its ``state_fields`` declare.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.agg.registry import AggregatorRule

__all__ = ["AggState", "init_state"]


class AggState(NamedTuple):
    """Carried state of a stateful aggregation rule (a jit-able pytree).

    step:     () int32 — aggregations absorbed so far.
    history:  sliding-window gradient buffer(s), or ``()``.
    center:   momentum-carried center leaves, or ``()``.
    bus:      async runtime's ``GradientBus`` slots + versions, or ``()``.
    reputation: per-worker fp32 trust scores in [0, 1], or ``()``.
    obs:      telemetry ``MetricsBuffer`` forensics ring, or ``()``.
    """

    step: jnp.ndarray
    history: Any = ()
    center: Any = ()
    bus: Any = ()
    reputation: Any = ()
    obs: Any = ()


def init_state(rule: AggregatorRule, template: Any,
               flat: "bool | None" = None, *,
               rep_dims: Tuple[int, ...] = ()) -> AggState:
    """Zero-initialized :class:`AggState` for one rule and gradient shape.

    Args:
      rule: the resolved rule; ``rule.state_fields`` selects which
        buffers to allocate and ``rule.history_window`` their window.
      template: the worker-stacked gradients the rule will see — either
        a flat ``(n, d)`` array (dense path) or a pytree of
        ``(n, *dims)`` leaves (tree path).  Only shapes are read, so
        ``jax.ShapeDtypeStruct`` trees work (and keep ``jax.eval_shape``
        usable for abstract initialization).
      flat: layout of the buffers — True for the dense path (buffers are
        single arrays), False for the tree path (buffers are tuples of
        per-leaf arrays, the layout ``rule.tree_fn`` consumes).  The
        default infers it from ``template``: a bare array means dense.
        Pass ``flat=False`` explicitly when feeding a *bare-array
        pytree* to ``distributed_aggregate`` (which does so itself when
        it self-initializes).
      rep_dims: extra trailing dimensions of the ``reputation`` buffer
        beyond the leading worker axis — ``()`` gives the training
        layout ``(n,)``; the serving layer passes ``(batch,)`` for
        per-slot ``(n, batch)`` reputation columns
        (``repro.dist.serve_robust.init_ensemble_state``).

    Returns:
      An :class:`AggState` with ``step = 0`` and fp32 zero buffers for
      exactly the fields in ``rule.state_fields``; a stateless rule gets
      ``AggState(0, (), (), (), ())``.  A rule declaring ``"bus"`` gets
      a zeroed ``GradientBus`` whose slots mirror the template's own
      structure and dtypes (rules only read ``bus.versions``; the async
      step owns the slots); a rule declaring ``"reputation"`` gets a
      **ones** buffer (neutral trust — uniform reputation reproduces
      the base rule bitwise); a rule declaring ``"obs"`` gets an empty
      ``MetricsBuffer`` ring of ``rule.obs_capacity`` rows sized to the
      template's worker axis.
    """
    leaves = jax.tree_util.tree_leaves(template)
    dense = (flat if flat is not None
             else len(leaves) == 1 and leaves[0] is template)
    history: Any = ()
    center: Any = ()
    bus: Any = ()
    reputation: Any = ()
    obs: Any = ()
    if "history" in rule.state_fields:
        w = rule.history_window
        if not w or w < 1:
            raise ValueError(
                f"rule {rule.name!r} needs a positive history_window, "
                f"got {w!r}")
        bufs = [jnp.zeros((w,) + leaf.shape, jnp.float32)
                for leaf in leaves]
        history = bufs[0] if dense else tuple(bufs)
    if "center" in rule.state_fields:
        cs = [jnp.zeros(leaf.shape[1:], jnp.float32) for leaf in leaves]
        center = cs[0] if dense else tuple(cs)
    if "bus" in rule.state_fields:
        from repro.dist.async_train import init_bus
        bus = init_bus(template)
    if "reputation" in rule.state_fields:
        n = leaves[0].shape[0]
        reputation = jnp.ones((n,) + tuple(rep_dims), jnp.float32)
    if "obs" in rule.state_fields:
        from repro.obs.buffer import (DEFAULT_OBS_CAPACITY,
                                      init_metrics_buffer)
        obs = init_metrics_buffer(
            rule.obs_capacity or DEFAULT_OBS_CAPACITY,
            leaves[0].shape[0])
    return AggState(step=jnp.zeros((), jnp.int32), history=history,
                    center=center, bus=bus, reputation=reputation,
                    obs=obs)
