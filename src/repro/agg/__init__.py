"""Unified aggregation-rule registry (the rule layer, end to end).

Public API::

    from repro.agg import resolve_rule, AggSpec, AggState, init_state

    rule = resolve_rule("bulyan-krum")          # one string resolver
    res = rule.dense_fn(grads, f)               # flat (n, d) path

    rule = resolve_rule("buffered-cwmed")       # stateful history rule
    state = init_state(rule, grads)             # zeroed AggState
    res, state = rule.dense_fn(grads, f, state)

The registry (``repro.agg.registry``) is the single dispatch point for
every layer: ``repro.core.gars`` registers the dense rule math,
``repro.agg.tree`` / ``repro.agg.buffered`` the tree-path and stateful
implementations, and ``repro.dist.robust`` / ``repro.training.trainer``
resolve by name.  ``repro.agg.specs`` merges the two historic spec
dataclasses into :class:`AggSpec` (old import paths still work).
"""
from repro.agg.registry import (AggregatorRule, TreeAgg, TreeContext,
                                quorum, register_rule, register_tree_impl,
                                resolve_rule, rule_names)
from repro.agg.specs import AggSpec, check_quorum
from repro.agg.state import AggState, init_state
from repro.agg.buffered import centered_clip_momentum, make_buffered
from repro.agg.staleness import make_stale, stale_scale, stale_weights
from repro.agg.reputation import (make_reputation, reputation_scale,
                                  reputation_scores, step_size_multiplier,
                                  tree_reputation_scores, update_reputation)

__all__ = [
    "AggSpec", "AggState", "AggregatorRule", "TreeAgg", "TreeContext",
    "centered_clip_momentum", "check_quorum", "init_state",
    "make_buffered", "make_reputation", "make_stale", "quorum",
    "register_rule", "register_tree_impl", "reputation_scale",
    "reputation_scores", "resolve_rule", "rule_names", "stale_scale",
    "stale_weights", "step_size_multiplier", "tree_reputation_scores",
    "update_reputation",
]
