"""Stateful aggregation: history-buffered rules and momentum centered-clip.

The ``buffered-<base>`` family implements Alistarh et al. 2018-style
aggregation over a sliding window ("Byzantine Stochastic Gradient
Descent", arXiv:1803.08917): each worker's last W submissions are kept in
a per-worker history buffer, the rule first *means* each worker's window
(variance reduction the adversary cannot rewrite retroactively — a
Byzantine worker is judged on its whole recent trajectory), then applies
the base rule to the smoothed submissions — medians-of-means when the
base is ``cwmed``.  The buffer lives in an explicit ``AggState`` carried
by the caller, so the rules stay pure and jit-able and stateless rules
pay nothing.

``centered_clip_momentum`` is the momentum-carried variant of the
``centered_clip`` baseline (Karimireddy et al. 2021): the clipping
center starts from the previous step's converged center instead of the
current mean, which is what makes the defense robust to time-coupled
attacks.  Its stateless fixed-point body is shared with the tree-path
``centered_clip`` implementation that used to live in
``repro.dist.robust._centered_clip_tree``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.agg.registry import (AggregatorRule, TreeAgg, TreeContext,
                                register_rule, register_tree_impl)
from repro.agg.state import AggState
from repro.core.types import AggResult

__all__ = ["centered_clip_momentum", "make_buffered"]

_TAU = 10.0
_ITERS = 3


def _trailing_axes(leaf) -> Tuple[int, ...]:
    return tuple(range(1, leaf.ndim))


def _clip_fixed_point(leaves: Sequence[jnp.ndarray], n: int, cdt, v0,
                      tau: float = _TAU, iters: int = _ITERS):
    """Iteratively clip worker deviations from a running center.

    The per-worker deviation norm is the *global* norm across leaves,
    matching the flat reference (``repro.core.gars.centered_clip``).

    Args:
      leaves: worker-stacked ``(n, *dims)`` leaves, already in ``cdt``.
      n: worker count.
      cdt: compute dtype.
      v0: tuple of initial center leaves (``(*dims,)`` each).
      tau: clipping radius.
      iters: fixed-point iterations.

    Returns:
      Tuple of converged center leaves.
    """
    def body(_, v):
        deltas = [l - vi[None] for l, vi in zip(leaves, v)]
        norm2 = jnp.zeros((n,), cdt)
        for dlt in deltas:
            norm2 = norm2 + jnp.sum(dlt * dlt, axis=_trailing_axes(dlt))
        norm = jnp.sqrt(norm2)
        scale = jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-12))
        return tuple(
            vi + jnp.mean(dlt * scale.reshape((n,) + (1,) * (dlt.ndim - 1)),
                          axis=0)
            for vi, dlt in zip(v, deltas))

    return jax.lax.fori_loop(0, iters, body, tuple(v0))


@register_tree_impl("centered_clip")
def _centered_clip_tree(ctx: TreeContext) -> TreeAgg:
    leaves = [l.astype(ctx.cdt) for l in ctx.leaves]
    v0 = [jnp.mean(l, axis=0) for l in leaves]
    v = _clip_fixed_point(leaves, ctx.n, ctx.cdt, v0)
    return TreeAgg(list(v), ctx.uniform(), ctx.zeros())


@register_rule("centered_clip_momentum", min_n=lambda f: 2 * f + 1,
               stateful=True, state_fields=("center",),
               # the carried center is a previous step's fixed point and
               # may legitimately sit outside the *current* stack's hull
               invariants=("finite",),
               doc="centered clipping with the center carried across steps")
def centered_clip_momentum(grads: jnp.ndarray, f: int,
                           state: AggState) -> Tuple[AggResult, AggState]:
    """Momentum-carried centered clipping on a flat ``(n, d)`` matrix.

    Args:
      grads: ``(n, d)`` worker-stacked gradients.
      f: Byzantine bound (unused by the clip itself; kept for the
        uniform rule signature).
      state: carried ``AggState``; ``state.center`` seeds the clipping
        center from step 1 on (step 0 falls back to the current mean).

    Returns:
      ``(AggResult, new_state)`` with the converged center stored back
      into ``state.center``.
    """
    del f
    n = grads.shape[0]
    g = grads.astype(jnp.float32)
    mean = jnp.mean(g, axis=0)
    v0 = jnp.where(state.step == 0, mean, state.center)
    (v,) = _clip_fixed_point([g], n, jnp.float32, [v0])
    res = AggResult(v.astype(grads.dtype),
                    jnp.full((n,), 1.0 / n, grads.dtype),
                    jnp.zeros((n,), grads.dtype))
    return res, state._replace(step=state.step + 1, center=v)


@register_tree_impl("centered_clip_momentum")
def _centered_clip_momentum_tree(ctx: TreeContext, state: AggState
                                 ) -> Tuple[TreeAgg, AggState]:
    leaves = [l.astype(ctx.cdt) for l in ctx.leaves]
    means = [jnp.mean(l, axis=0) for l in leaves]
    v0 = [jnp.where(state.step == 0, m, c.astype(ctx.cdt))
          for m, c in zip(means, state.center)]
    v = _clip_fixed_point(leaves, ctx.n, ctx.cdt, v0)
    new = state._replace(step=state.step + 1,
                         center=tuple(c.astype(jnp.float32) for c in v))
    return TreeAgg(list(v), ctx.uniform(), ctx.zeros()), new


def _window_update(history, grads, step, window: int):
    """Write ``grads`` into the ring buffer and return (buffer, smoothed)."""
    slot = jnp.mod(step, window)
    hist = jax.lax.dynamic_update_index_in_dim(
        history, grads.astype(history.dtype), slot, 0)
    filled = jnp.minimum(step + 1, window).astype(history.dtype)
    return hist, jnp.sum(hist, axis=0) / filled


def make_buffered(name: str, base: AggregatorRule,
                  window: int) -> AggregatorRule:
    """Build the ``buffered-<base>`` composite around a stateless rule.

    Per step, the current submissions are written into a per-worker ring
    buffer of the last ``window`` steps (zero-padded until full, so the
    early-step means run over the filled prefix), each worker's window
    is averaged, and ``base`` aggregates the smoothed submissions.

    Args:
      name: composite registry name (``"buffered-<base>"``).
      base: the resolved stateless base rule; both its dense and tree
        implementations are wrapped (the tree side only when the base
        has one).
      window: sliding-window length W >= 1.

    Returns:
      A stateful :class:`AggregatorRule` with ``state_fields =
      ("history",)`` and the base's quorum.
    """
    if window < 1:
        raise ValueError(f"history window must be >= 1, got {window}")

    def dense(grads, f, state):
        hist, smoothed = _window_update(state.history, grads, state.step,
                                        window)
        res = base.dense_fn(smoothed.astype(grads.dtype), f)
        return res, state._replace(step=state.step + 1, history=hist)

    tree_fn = None
    if base.tree_fn is not None:
        def tree_fn(ctx, state):
            pairs = [_window_update(h, l, state.step, window)
                     for h, l in zip(state.history, ctx.leaves)]
            hist = tuple(h for h, _ in pairs)
            smoothed = [s for _, s in pairs]
            out = base.tree_fn(ctx.with_leaves(smoothed))
            return out, state._replace(step=state.step + 1, history=hist)

    return AggregatorRule(
        name=name, min_n=base.min_n, dense_fn=dense, tree_fn=tree_fn,
        byzantine_resilient=base.byzantine_resilient, stateful=True,
        state_fields=("history",), history_window=window,
        # the base's invariants hold relative to the *smoothed* stack it
        # consumed (the audit recomputes the window means)
        invariants=base.invariants,
        doc=f"window-{window} history means fed to {base.name} "
            f"(Alistarh et al. 2018-style)")
