"""Reputation-weighted aggregation: the ``reputation-<base>`` family.

Every quorum-bound rule in the registry inherits the paper's worker-count
arithmetic — Krum needs ``n >= 2f + 3``, Bulyan ``n >= 4f + 3`` — so none
of them can even *run* once half the committee is Byzantine.  ByGARS
(Regatti et al., arXiv:2006.13421) shows a different contract: learn a
per-worker **reputation score** from how well each submission agrees with
a trusted signal (the emitted aggregate, or a gradient computed on an
auxiliary clean batch), down-weight low-reputation workers before any
rule runs, and the defense tolerates an *arbitrary* number of attackers —
the one threat-model axis the quorum family cannot express.

``reputation-<base>`` wraps **any** registered base rule through the
unchanged registry (``resolve_rule("reputation-krum")``, nesting with the
``stale-`` / ``buffered-`` / ``fused-`` / ``bulyan-`` families in either
direction).  Per step it:

1. reads per-worker scores ``rep`` from the carried
   :class:`~repro.agg.state.AggState` (``reputation`` field, initialized
   to ones) and normalizes weights ``w = rep / max(rep)`` so the most
   trusted worker keeps scale exactly 1 and nobody is amplified;
2. replaces each worker's row by the **reputation blend**
   ``w_i * g_i + (1 - w_i) * g_w`` where ``g_w`` is the
   reputation-weighted mean — a fully distrusted worker degenerates into
   echoing the trusted consensus instead of submitting a zero row (pure
   scaling cannot defeat a colluding majority: identical colluding rows
   stay a tight selection-winning cluster at any scale, and zeroed rows
   cluster at the origin and freeze training).  Rows with ``w_i == 1``
   pass through untouched, so **uniform reputation reproduces the base
   rule bitwise**;
3. clamps the Byzantine bound to the largest ``f' <= f`` the base's
   quorum admits at this ``n`` (``reputation-<base>`` itself only
   requires ``base.min_n(0)`` workers — the arbitrary-f contract);
4. runs the base rule on the blended stack, then updates the scores by
   an EMA of the cosine agreement between each worker's **raw** row and
   the emitted aggregate:
   ``rep <- clip(rep_decay * ((1 - rep_lr) * rep + rep_lr * s), 0, 1)``
   with ``s = (1 + cos) / 2 in [0, 1]``.

When an auxiliary clean batch is available (``AggSpec(aux_batch=...)``),
the trainer scores agreement against the clean-batch gradient instead —
the ByGARS mechanism proper, and what breaks the bootstrap circularity
under a colluding majority (agreement with an aggregate the colluders
already own would *reward* them).  The same state doubles as the
staleness-adaptive learning-rate tail of Alistarh et al.
(arXiv:1803.08917): :func:`step_size_multiplier` maps the carried scores
to a scalar in ``(0, 1]`` the train steps multiply into the update when
``spec.rep_lr`` is set, so a distrusted committee also takes smaller
steps, not just reweighted ones.

See docs/reputation.md for the threat-model table (which rules survive
which f regime) and the serving-side per-slot ``(n, batch)`` layout.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.agg.registry import AggregatorRule
from repro.agg.state import AggState

__all__ = ["DEFAULT_REP_DECAY", "DEFAULT_REP_LR", "blend_stack",
           "make_reputation", "reputation_scale", "reputation_scores",
           "step_size_multiplier", "tree_reputation_scores",
           "update_reputation"]

#: EMA rate of the per-step reputation update (``rep_lr``)
DEFAULT_REP_LR = 0.5

#: multiplicative forgetting factor applied after the EMA (``rep_decay``);
#: 1.0 = no decay — reputation is forgotten only through disagreement
DEFAULT_REP_DECAY = 1.0

_EPS = 1e-12


def reputation_scale(state: AggState) -> jnp.ndarray:
    """Per-worker weights ``w = rep / max(rep)`` in ``(0, 1]``.

    Normalizing by the best-trusted worker means at least one weight is
    exactly 1 (``x / x == 1.0`` in floating point), nobody is ever
    amplified, and a fresh all-ones reputation yields weights of exactly
    1.0 everywhere — the anchor of the bitwise base-identity contract.
    The serving layout ``(n, batch)`` normalizes per slot (max over the
    worker axis, per column).

    Args:
      state: carried ``AggState`` with an allocated ``reputation``
        buffer — ``(n,)`` training layout or ``(n, batch)`` serving
        layout.

    Returns:
      Weights with the same shape as ``state.reputation``, fp32, in
      ``(0, 1]``.
    """
    rep = state.reputation.astype(jnp.float32)
    m = jnp.max(rep, axis=0, keepdims=True)
    return rep / jnp.maximum(m, _EPS)


def reputation_scores(grads: jnp.ndarray, target: jnp.ndarray, *,
                      rep_ndim: int = 1) -> jnp.ndarray:
    """Cosine-agreement scores in ``[0, 1]`` against a trusted target.

    The score is ``(1 + cos(g_i, target)) / 2`` — 1 for a worker aligned
    with the target, 0 for a sign-flipped one, 0.5 for an orthogonal (or
    zero) submission.  Computed in fp32 regardless of input dtypes.

    Args:
      grads: worker-stacked ``(n, *dims)`` submissions (the **raw** rows,
        pre-blend — workers are judged on what they sent).
      target: the trusted signal with shape ``dims`` — the emitted
        aggregate, or an auxiliary clean-batch gradient.
      rep_ndim: rank of the score array — 1 contracts everything after
        the worker axis into one ``(n,)`` score; 2 keeps the second axis
        (the serving layer's per-slot ``(n, batch)`` scores over
        ``(n, batch, vocab)`` logits stacks).

    Returns:
      ``(n,)`` (or ``(n, batch)``) fp32 scores in ``[0, 1]``.
    """
    g = grads.astype(jnp.float32)
    t = target.astype(jnp.float32)
    red = tuple(range(rep_ndim, g.ndim))
    tred = tuple(range(rep_ndim - 1, t.ndim))
    num = jnp.sum(g * t[None], axis=red)
    g2 = jnp.sum(g * g, axis=red)
    t2 = jnp.sum(t * t, axis=tred)
    cos = num / (jnp.sqrt(g2) * jnp.sqrt(t2)[None] + _EPS)
    return 0.5 * (1.0 + cos)


def update_reputation(rep: jnp.ndarray, scores: jnp.ndarray,
                      rep_lr: float = DEFAULT_REP_LR,
                      rep_decay: float = DEFAULT_REP_DECAY) -> jnp.ndarray:
    """One EMA step of the reputation schedule, clipped into ``[0, 1]``.

    ``rep <- clip(rep_decay * ((1 - rep_lr) * rep + rep_lr * scores),
    0, 1)``.  The clip also repairs out-of-range values flowing in from
    a corrupted checkpoint restore — reputation can never amplify
    (``> 1``) or go negative, mirroring the staleness clamp of
    ``repro.agg.staleness.stale_scale``.

    Args:
      rep: current ``(n,)`` / ``(n, batch)`` reputation.
      scores: agreement scores in ``[0, 1]``, same shape
        (:func:`reputation_scores`).
      rep_lr: EMA rate in ``[0, 1]`` — 0 freezes reputation, 1 replaces
        it with the instantaneous score.
      rep_decay: multiplicative forgetting factor in ``(0, 1]`` applied
        after the EMA; values below 1 make trust *erode* unless
        continuously re-earned (the defense against slowly-built-then-
        burned reputation).

    Returns:
      Updated reputation, fp32, clipped into ``[0, 1]``.
    """
    new = (1.0 - rep_lr) * rep.astype(jnp.float32) \
        + rep_lr * scores.astype(jnp.float32)
    return jnp.clip(rep_decay * new, 0.0, 1.0)


def step_size_multiplier(state: AggState) -> jnp.ndarray:
    """Scalar learning-rate multiplier in ``(0, 1]`` from carried trust.

    The mean of the normalized weights ``w = rep / max(rep)``: a fully
    trusted committee multiplies by exactly 1 (bitwise no-op), while a
    committee whose scores have collapsed shrinks the step — the
    staleness-adaptive step-size rule of Alistarh et al. folded onto the
    same state that reweights the stack.  Threaded into
    ``make_train_step`` / ``make_async_train_step`` (and the flat
    trainer) when ``spec.rep_lr`` is set.

    Args:
      state: carried ``AggState`` with an allocated ``reputation``
        buffer.

    Returns:
      fp32 scalar in ``(0, 1]``.
    """
    return jnp.mean(reputation_scale(state))


def blend_stack(leaf: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reputation blend of one worker-stacked leaf (bitwise at w == 1).

    Each worker row becomes ``w_i * g_i + (1 - w_i) * g_w`` with ``g_w``
    the reputation-weighted mean over the worker axis — a distrusted row
    degenerates into echoing the trusted consensus.  Rows whose weight is
    exactly 1 pass through untouched (the where-guard below), which is
    what makes uniform reputation reproduce the base rule bitwise.  Also
    used by ``repro.audit.invariants`` to replay the transformation the
    rule body applied.

    Args:
      leaf: worker-stacked ``(n, *dims)`` array (gradient leaf or logits
        stack).
      w: weights in ``[0, 1]`` of shape ``(n,)`` — or ``(n, batch)`` for
        the serving layout — broadcast over the trailing dims.

    Returns:
      The blended stack, same shape and dtype as ``leaf``.
    """
    wr = w.reshape(w.shape + (1,) * (leaf.ndim - w.ndim)).astype(leaf.dtype)
    den = jnp.maximum(jnp.sum(w, axis=0), _EPS).astype(leaf.dtype)
    wmean = jnp.sum(wr * leaf, axis=0) \
        / den.reshape(den.shape + (1,) * (leaf.ndim - 1 - den.ndim))
    # the where-guard (not algebraic simplification) carries the bitwise
    # contract: w == 1 must return the row itself, untouched by -0.0 /
    # rounding artifacts of the blend arithmetic
    return jnp.where(wr == 1.0, leaf, wr * leaf + (1.0 - wr) * wmean[None])


def tree_reputation_scores(leaves: Sequence[jnp.ndarray],
                           agg_leaves: Sequence[jnp.ndarray],
                           rep_ndim: int = 1) -> jnp.ndarray:
    """Tree-path :func:`reputation_scores`: cosine over all leaves at once.

    Accumulates the dot product and both squared norms per leaf (one
    contraction each — never materializing a flat ``(n, d)`` matrix,
    the sharded engine's invariant) and finalizes one global cosine over
    the concatenated coordinate space.  Used by the ``reputation-*``
    tree path and by the train steps' auxiliary clean-batch scoring
    (``AggSpec(aux_batch=...)``).

    Args:
      leaves: worker-stacked ``(n, *dims)`` gradient leaves (flat tree
        order).
      agg_leaves: trusted-target leaves with shapes ``dims`` — the
        emitted aggregate's leaves, or an auxiliary clean gradient's.
      rep_ndim: rank of the score array (see :func:`reputation_scores`).

    Returns:
      ``(n,)`` (or ``(n, batch)``) fp32 scores in ``[0, 1]``.
    """
    num = jnp.zeros((), jnp.float32)
    g2 = jnp.zeros((), jnp.float32)
    t2 = jnp.zeros((), jnp.float32)
    for leaf, agg in zip(leaves, agg_leaves):
        g = leaf.astype(jnp.float32)
        t = jnp.asarray(agg, jnp.float32)
        red = tuple(range(rep_ndim, g.ndim))
        tred = tuple(range(rep_ndim - 1, t.ndim))
        num = num + jnp.sum(g * t[None], axis=red)
        g2 = g2 + jnp.sum(g * g, axis=red)
        t2 = t2 + jnp.sum(t * t, axis=tred)
    cos = num / (jnp.sqrt(g2) * jnp.sqrt(t2)[None] + _EPS)
    return 0.5 * (1.0 + cos)


def _clamp_f(base: AggregatorRule, n: int, f: int) -> int:
    """Largest f' <= f the base quorum admits at this n (trace-time)."""
    f_eff = f
    while f_eff > 0 and base.min_n(f_eff) > n:
        f_eff -= 1
    return f_eff


def make_reputation(name: str, base: AggregatorRule,
                    rep_lr: float = DEFAULT_REP_LR,
                    rep_decay: float = DEFAULT_REP_DECAY) -> AggregatorRule:
    """Build the ``reputation-<base>`` composite around any registered rule.

    The composite is stateful with ``"reputation"`` prepended to the
    base's ``state_fields``.  Its quorum is ``base.min_n(0)`` — a
    *constant* in f, the arbitrary-f contract: the declared Byzantine
    bound is clamped to what the base admits at the actual worker count
    (identity whenever ``f`` already satisfies the base quorum), because
    the defense lives in the reputation blend, not in worker-count
    arithmetic.  A stateful base composes — the same ``AggState``
    carries both the reputation scores and the base's buffers — but
    nesting two reputation layers is rejected by the resolver.

    Args:
      name: composite registry name (``"reputation-<base>"``).
      base: the resolved base rule; its tree implementation is wrapped
        only when it has one.
      rep_lr: EMA rate of the per-step score update
        (:func:`update_reputation`).
      rep_decay: multiplicative forgetting factor of the schedule.

    Returns:
      A stateful :class:`AggregatorRule` with ``min_n = base.min_n(0)``
      (constant in f) and the base's invariants minus ``"trimmed"``
      (the f-trimmed-hull contract is stated at the *declared* f, which
      the clamp may legitimately reduce in the arbitrary-f regime).
    """
    state_fields: Tuple[str, ...] = (
        ("reputation",)
        + tuple(f for f in base.state_fields if f != "reputation"))
    min_n0 = base.min_n(0)

    def dense(grads, f, state):
        f_eff = _clamp_f(base, grads.shape[0], f)
        rep = state.reputation
        w = reputation_scale(state)
        scaled = blend_stack(grads, w.astype(grads.dtype))
        if base.stateful:
            res, state = base.dense_fn(scaled, f_eff, state)
        else:
            res = base.dense_fn(scaled, f_eff)
            state = state._replace(step=state.step + 1)
        scores = reputation_scores(grads, res.gradient, rep_ndim=rep.ndim)
        return res, state._replace(
            reputation=update_reputation(rep, scores, rep_lr, rep_decay))

    tree_fn = None
    if base.tree_fn is not None:
        def tree_fn(ctx, state):
            f_eff = _clamp_f(base, ctx.n, ctx.f)
            rep = state.reputation
            w = reputation_scale(state).astype(ctx.cdt)
            # blend in the accumulation dtype, then restore each leaf's
            # own dtype so the base rule sees the layout it always sees
            # (the round trip is exact at w == 1: the where-guard returns
            # the cast leaf, and casting back is lossless)
            scaled = [blend_stack(l.astype(ctx.cdt), w).astype(l.dtype)
                      for l in ctx.leaves]
            sctx = dataclasses.replace(ctx, leaves=tuple(scaled), f=f_eff)
            if base.stateful:
                out, state = base.tree_fn(sctx, state)
            else:
                out = base.tree_fn(sctx)
                state = state._replace(step=state.step + 1)
            scores = tree_reputation_scores(ctx.leaves, out.leaves,
                                            rep.ndim)
            return out, state._replace(
                reputation=update_reputation(rep, scores, rep_lr,
                                             rep_decay))

    return AggregatorRule(
        name=name, min_n=lambda f: min_n0, dense_fn=dense, tree_fn=tree_fn,
        byzantine_resilient=base.byzantine_resilient, stateful=True,
        state_fields=state_fields, history_window=base.history_window,
        # base invariants hold relative to the *blended* stack (the audit
        # replays the blend); "trimmed" is stated at the declared f and
        # may be weakened by the arbitrary-f clamp, so it is dropped
        invariants=tuple(i for i in base.invariants if i != "trimmed"),
        doc=f"reputation-blended worker stack fed to {base.name} "
            f"(ByGARS-style, arbitrary-f)")
