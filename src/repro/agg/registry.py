"""The unified aggregation-rule registry.

Every gradient aggregation rule (GAR) in the repo — the paper's rules
(§2.3/§4), the beyond-paper baselines, and the stateful buffered family —
is described by one :class:`AggregatorRule` record and resolved through
one string resolver, :func:`resolve_rule`.  The three layers that used to
carry their own ``if gar == ...`` dispatch chains (``repro.core.gars``,
``repro.dist.robust.distributed_aggregate``, ``repro.training.trainer``)
all consume this registry instead:

* the **dense** path calls ``rule.dense_fn(grads, f)`` on a flat
  ``(n, d)`` matrix (``(grads, f, state)`` for stateful rules);
* the **tree** path calls ``rule.tree_fn(ctx)`` with a
  :class:`TreeContext` built by the sharded engine in
  ``repro.dist.robust`` (``(ctx, state)`` for stateful rules).

Composite families are resolved on demand: ``"bulyan-<base>"`` wraps the
base rule in Bulyan's two phases (``repro.core.bulyan``),
``"buffered-<base>"`` wraps it with the per-worker sliding-window history
buffer of ``repro.agg.buffered`` (Alistarh et al. 2018-style), and
``"stale-<base>"`` (``"stale-inv-"`` / ``"stale-exp-"`` select the
weight schedule) reweights the worker stack by per-worker staleness read
from the carried ``GradientBus`` before delegating to the base
(``repro.agg.staleness`` — the asynchronous runtime's rule family), and
``"fused-<base>"`` lowers the base onto the single-sweep Pallas
megakernel (``repro.agg.fused`` / ``repro.kernels.fused_agg``) with the
base's quorum and invariant contract intact, and
``"reputation-<base>"`` blends the worker stack by carried per-worker
trust scores before delegating (``repro.agg.reputation`` — the
arbitrary-f family whose quorum is constant in f), and
``"obs-<base>"`` records per-call aggregation forensics into the
carried ``MetricsBuffer`` ring with the base's data path bitwise
untouched (``repro.obs.forensics`` — the telemetry family).
Resolved composites are cached, so repeated lookups are dict hits.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple)

import jax.numpy as jnp

__all__ = ["AggregatorRule", "TreeAgg", "TreeContext", "quorum",
           "register_rule", "register_tree_impl", "resolve_rule",
           "rule_names"]

#: default sliding-window length of the ``buffered-*`` family
DEFAULT_HISTORY_WINDOW = 4


class TreeAgg(NamedTuple):
    """Output of one tree-path rule application.

    leaves:    aggregated per-parameter leaves in the compute dtype
               (the engine casts them back to the input dtypes).
    selected:  (n,) worker weights in the output (diagnostic).
    scores:    (n,) per-worker rule scores (lower = better), or zeros.
    """

    leaves: List[jnp.ndarray]
    selected: jnp.ndarray
    scores: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TreeContext:
    """Everything a tree-path rule may consume, prepared by the engine.

    The sharded engine (``repro.dist.robust.distributed_aggregate``)
    owns the expensive machinery — the distance backend dispatch
    (xla / shard-mapped Pallas) and the windowed coordinate phase — and
    hands it to rules through this context, so rule bodies stay
    backend- and mesh-agnostic.

    Args:
      leaves: tuple of ``(n, *dims)`` worker-stacked gradient leaves in
        their input dtypes (rules cast to ``cdt`` as needed).
      n: worker count (static).
      f: Byzantine bound (static).
      cdt: accumulation/compute dtype (fp32 contract by default).
      make_dists: callable mapping a leaves sequence to the ``(n, n)``
        squared-distance matrix via the configured distance backend.
      coordinate_phase: ``(stack, f) -> agg`` — the engine's windowed
        Bulyan phase 2 (``coordinate_phase_nd`` with the window bound).
    """

    leaves: Tuple[jnp.ndarray, ...]
    n: int
    f: int
    cdt: Any
    make_dists: Callable[[Sequence[jnp.ndarray]], jnp.ndarray]
    coordinate_phase: Callable[[jnp.ndarray, int], jnp.ndarray]

    def dists(self) -> jnp.ndarray:
        """Squared-distance matrix of this context's leaves.

        Args:
          (none) — operates on ``self.leaves``.

        Returns:
          ``(n, n)`` squared euclidean distances over the concatenated
          coordinate space, in ``cdt``.
        """
        return self.make_dists(self.leaves)

    def with_leaves(self, leaves: Sequence[jnp.ndarray]) -> "TreeContext":
        """A copy of this context over different (same-shaped) leaves.

        Args:
          leaves: replacement worker-stacked leaves, same flat order.

        Returns:
          A new :class:`TreeContext`; ``dists()`` recomputes over the
          new leaves through the same backend closure.
        """
        return dataclasses.replace(self, leaves=tuple(leaves))

    def uniform(self) -> jnp.ndarray:
        """Uniform ``(n,)`` selection weights ``1/n`` in ``cdt``.

        Args:
          (none).

        Returns:
          ``(n,)`` array of ``1/n``.
        """
        return jnp.full((self.n,), 1.0 / self.n, self.cdt)

    def zeros(self) -> jnp.ndarray:
        """All-zero ``(n,)`` score vector in ``cdt``.

        Args:
          (none).

        Returns:
          ``(n,)`` zeros.
        """
        return jnp.zeros((self.n,), self.cdt)

    def take_worker(self, i) -> List[jnp.ndarray]:
        """Select one worker's row from every leaf (traced index).

        Args:
          i: traced or static worker index.

        Returns:
          List of ``(*dims,)`` leaves in ``cdt``.
        """
        return [jnp.take(leaf, i, axis=0).astype(self.cdt)
                for leaf in self.leaves]

    def weighted_sum(self, weights: jnp.ndarray) -> List[jnp.ndarray]:
        """Per-leaf ``<weights, workers>`` contraction.

        The ``(n,)`` weights stay tiny and replicated; each leaf
        contracts its own worker axis, preserving leaf sharding.

        Args:
          weights: ``(n,)`` worker weights.

        Returns:
          List of ``(*dims,)`` combined leaves in ``cdt``.
        """
        w = weights.astype(self.cdt)
        return [jnp.tensordot(w, leaf.astype(self.cdt), axes=(0, 0))
                for leaf in self.leaves]


@dataclasses.dataclass
class AggregatorRule:
    """One registered aggregation rule (dense + tree implementations).

    name:       canonical registry key (e.g. ``"krum"``).
    min_n:      minimal worker count as a function of f (paper §2.3/§4).
    dense_fn:   flat-path callable ``(grads: (n, d), f) -> AggResult``
                (stateful: ``(grads, f, state) -> (AggResult, state)``).
    tree_fn:    tree-path callable ``(ctx: TreeContext) -> TreeAgg``
                (stateful: ``(ctx, state) -> (TreeAgg, state)``);
                ``None`` when the rule has no distributed form.
    byzantine_resilient: True when proven (alpha, f)-resilient.
    stateful:   True when the rule threads an ``AggState``.
    state_fields: which ``AggState`` fields the rule uses
                (subset of ``("history", "center")``).
    history_window: sliding-window length for history-buffered rules.
    invariants: declared output invariants the adversarial self-audit
                (``repro.audit``) asserts for this rule, each relative
                to the *effective* stack the rule body consumed (after
                staleness reweighting / history smoothing):
                  "finite"  output has no NaN/inf (every rule);
                  "hull"    per coordinate within [min, max] over
                            workers;
                  "trimmed" per coordinate within the f-trimmed range
                            [sorted[f], sorted[n-1-f]];
                  "convex"  ``selected`` is a convex-combination
                            certificate — nonnegative, sums to 1, and
                            ``gradient == selected @ stack``.
                Composites propagate their base's declaration; rules
                that legitimately break a property (e.g. the momentum-
                carried clipping center can leave the current hull)
                must not declare it.
    obs_capacity: ring rows ``init_state`` allocates for the telemetry
                ``MetricsBuffer`` — set only by the ``obs-<base>``
                family (``repro.obs.forensics``), ``None`` otherwise.
    doc:        one-line human description.
    """

    name: str
    min_n: Callable[[int], int]
    dense_fn: Optional[Callable] = None
    tree_fn: Optional[Callable] = None
    byzantine_resilient: bool = True
    stateful: bool = False
    state_fields: Tuple[str, ...] = ()
    history_window: Optional[int] = None
    invariants: Tuple[str, ...] = ("finite", "hull")
    obs_capacity: Optional[int] = None
    doc: str = ""

    @property
    def fn(self) -> Callable:
        """Back-compat alias for the pre-registry ``GarSpec.fn`` slot.

        Args:
          (none) — property.

        Returns:
          The dense-path callable.
        """
        return self.dense_fn


#: name -> AggregatorRule for every statically registered rule
RULES: Dict[str, AggregatorRule] = {}

#: tree implementations that arrived before (or after) their dense side —
#: registration is order-independent across the contributing modules
_TREE_IMPLS: Dict[str, Callable] = {}

#: (name, history_window, rep_lr, rep_decay) -> AggregatorRule cache for
#: resolved composites
_COMPOSITES: Dict[Tuple[str, int, float, float], AggregatorRule] = {}

_POPULATED = False


def register_rule(name: str, *, min_n: Callable[[int], int],
                  byzantine_resilient: bool = True, stateful: bool = False,
                  state_fields: Tuple[str, ...] = (),
                  invariants: Tuple[str, ...] = ("finite", "hull"),
                  doc: str = ""):
    """Decorator registering a dense-path rule implementation.

    Args:
      name: registry key; must be unique.
      min_n: minimal worker count as a function of f.
      byzantine_resilient: True when the rule is proven resilient.
      stateful: True when the dense fn threads an ``AggState``.
      state_fields: ``AggState`` fields the rule uses.
      invariants: declared output invariants the self-audit asserts
        (see :class:`AggregatorRule`).
      doc: one-line description for listings.

    Returns:
      A decorator that records the function as ``dense_fn`` and returns
      it unchanged.
    """
    def deco(fn):
        if name in RULES:
            raise ValueError(f"rule {name!r} registered twice")
        RULES[name] = AggregatorRule(
            name=name, min_n=min_n, dense_fn=fn,
            tree_fn=_TREE_IMPLS.get(name),
            byzantine_resilient=byzantine_resilient, stateful=stateful,
            state_fields=state_fields, invariants=invariants,
            doc=doc or (fn.__doc__ or "").strip()
            .split("\n")[0])
        return fn
    return deco


def register_tree_impl(name: str):
    """Decorator attaching a tree-path implementation to a rule.

    Order-independent with respect to the dense side: if the dense rule
    is not registered yet (the contributing modules import each other),
    the implementation is parked and attached when it arrives.

    Args:
      name: key of the rule the implementation belongs to.

    Returns:
      A decorator that records the function as ``tree_fn`` and returns
      it unchanged.
    """
    def deco(fn):
        _TREE_IMPLS[name] = fn
        if name in RULES:
            RULES[name].tree_fn = fn
        return fn
    return deco


def _populate() -> None:
    """Import the modules whose import side effect fills the registry."""
    global _POPULATED
    if _POPULATED:
        return
    _POPULATED = True
    import repro.core.gars      # noqa: F401  dense rules
    import repro.agg.tree       # noqa: F401  tree-path implementations
    import repro.agg.buffered   # noqa: F401  stateful rules


def _bulyan_rule(name: str) -> AggregatorRule:
    from functools import partial

    from repro.agg.tree import bulyan_tree
    from repro.core.bulyan import make_bulyan
    base = name.split("-", 1)[1] if "-" in name else "krum"
    # the distributed phase 1 works from distances alone, so only
    # distance-only bases get a tree implementation
    tree_fn = (partial(bulyan_tree, base=base)
               if base in ("krum", "geomed") else None)
    return AggregatorRule(
        name=name, min_n=lambda f: 4 * f + 3, dense_fn=make_bulyan(base),
        tree_fn=tree_fn, byzantine_resilient=True,
        # phase 2 averages a sorted window of the phase-1 picks — inside
        # the workers' per-coordinate hull, but `selected` marks the
        # theta picks with 1.0 (not convex weights)
        invariants=("finite", "hull"),
        doc=f"Bulyan({base}) — recursive selection + trimmed "
            f"coordinate phase")


def _buffered_rule(name: str, window: int) -> AggregatorRule:
    from repro.agg.buffered import make_buffered
    base = name.split("-", 1)[1] if "-" in name else "cwmed"
    base_rule = resolve_rule(base)
    if base_rule.stateful:
        raise KeyError(
            f"buffered-* needs a stateless base rule, got {base!r}")
    return make_buffered(name, base_rule, window)


def _stale_rule(name: str, window: int, rep_lr: float,
                rep_decay: float) -> AggregatorRule:
    from repro.agg.staleness import make_stale
    rest = name.split("-", 1)[1]
    weight = "inv"
    head = rest.split("-", 1)[0]
    if head in ("inv", "exp") and "-" in rest:
        weight, rest = rest.split("-", 1)
    # forward the reputation schedule so "stale-reputation-<base>"
    # nesting resolves the inner composite with the caller's params
    base_rule = resolve_rule(rest, history_window=window, rep_lr=rep_lr,
                             rep_decay=rep_decay)
    if "bus" in base_rule.state_fields:
        raise KeyError(
            f"stale-* cannot nest another stale rule, got {rest!r}")
    return make_stale(name, base_rule, weight=weight)


def _reputation_rule(name: str, window: int, rep_lr: float,
                     rep_decay: float) -> AggregatorRule:
    from repro.agg.reputation import make_reputation
    rest = name.split("-", 1)[1]
    base_rule = resolve_rule(rest, history_window=window, rep_lr=rep_lr,
                             rep_decay=rep_decay)
    if "reputation" in base_rule.state_fields:
        raise KeyError(
            f"reputation-* cannot nest another reputation rule, "
            f"got {rest!r}")
    return make_reputation(name, base_rule, rep_lr=rep_lr,
                           rep_decay=rep_decay)


def _obs_rule(name: str, window: int, rep_lr: float,
              rep_decay: float) -> AggregatorRule:
    from repro.obs.forensics import make_obs
    rest = name.split("-", 1)[1]
    base_rule = resolve_rule(rest, history_window=window, rep_lr=rep_lr,
                             rep_decay=rep_decay)
    if "obs" in base_rule.state_fields:
        raise KeyError(
            f"obs-* cannot nest another obs rule, got {rest!r}")
    return make_obs(name, base_rule)


def resolve_rule(name: str, history_window: Optional[int] = None,
                 rep_lr: Optional[float] = None,
                 rep_decay: Optional[float] = None) -> AggregatorRule:
    """Resolve a rule name to its :class:`AggregatorRule` record.

    This is the single string->rule resolver every layer dispatches
    through.  Plain names hit the static registry; ``"bulyan-<base>"``
    and ``"buffered-<base>"`` build (and cache) composite rules.

    Args:
      name: rule name — a registered key, ``"bulyan-<base>"``,
        ``"buffered-<base>"``, ``"stale[-inv|-exp]-<base>"``,
        ``"fused-<base>"``, ``"reputation-<base>"``, or
        ``"obs-<base>"`` (bases may nest, e.g.
        ``"buffered-bulyan-krum"``, ``"stale-exp-bulyan-krum"``,
        ``"stale-fused-krum"``, ``"reputation-stale-krum"``,
        ``"obs-stale-reputation-krum"``).
      history_window: sliding-window length for ``buffered-*`` rules
        (``None`` = :data:`DEFAULT_HISTORY_WINDOW`; ignored otherwise;
        forwarded through ``stale-*`` to a buffered base).
      rep_lr: EMA rate of the ``reputation-*`` score schedule (``None``
        = ``repro.agg.reputation.DEFAULT_REP_LR``; ignored by other
        rules; forwarded through wrapper prefixes to a nested
        reputation base).
      rep_decay: multiplicative forgetting factor of the ``reputation-*``
        schedule (``None`` = ``DEFAULT_REP_DECAY``; same forwarding).

    Returns:
      The resolved :class:`AggregatorRule`.  Raises ``KeyError`` for
      unknown names.
    """
    _populate()
    if name in RULES:
        return RULES[name]
    from repro.agg.reputation import DEFAULT_REP_DECAY, DEFAULT_REP_LR
    window = (DEFAULT_HISTORY_WINDOW if history_window is None
              else int(history_window))
    lr = DEFAULT_REP_LR if rep_lr is None else float(rep_lr)
    decay = DEFAULT_REP_DECAY if rep_decay is None else float(rep_decay)
    key = (name, window, lr, decay)
    if key in _COMPOSITES:
        return _COMPOSITES[key]
    if name.startswith("bulyan"):
        rule = _bulyan_rule(name)
    elif name.startswith("buffered"):
        rule = _buffered_rule(name, window)
    elif name.startswith("stale-"):
        # exact-prefix match: a dash-less "stale..." typo (or the
        # stale_replay *attack* name passed as a GAR) must hit the
        # unknown-name error below, not fall back to a default base
        rule = _stale_rule(name, window, lr, decay)
    elif name.startswith("reputation-"):
        rule = _reputation_rule(name, window, lr, decay)
    elif name.startswith("obs-"):
        rule = _obs_rule(name, window, lr, decay)
    elif name.startswith("fused-"):
        from repro.agg.fused import make_fused
        rule = make_fused(name)
    else:
        raise KeyError(
            f"unknown GAR {name!r}; have {sorted(RULES)} plus "
            f"'bulyan-<base>', 'buffered-<base>', 'stale-<base>', "
            f"'fused-<base>', 'reputation-<base>' and 'obs-<base>'")
    _COMPOSITES[key] = rule
    return rule


def rule_names() -> List[str]:
    """Names of every statically registered rule (composites excluded).

    Args:
      (none).

    Returns:
      Sorted list of registry keys; ``bulyan-<base>`` /
      ``buffered-<base>`` / ``stale-<base>`` resolve on top of these
      via :func:`resolve_rule`.
    """
    _populate()
    return sorted(RULES)


def quorum(name: str, f: int) -> int:
    """Minimal worker count for a rule at a given Byzantine bound.

    Args:
      name: any name :func:`resolve_rule` accepts.
      f: Byzantine bound.

    Returns:
      The smallest n the rule supports for this f.
    """
    return resolve_rule(name).min_n(f)
