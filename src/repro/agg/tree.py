"""Tree-path implementations of the stateless aggregation rules.

These are the bodies that used to live inline in the ``if gar == ...``
chain of ``repro.dist.robust.distributed_aggregate``.  Each consumes a
``TreeContext`` prepared by that engine (leaves with a leading worker
axis, a lazy distance-matrix closure over the configured backend, the
windowed coordinate phase) and returns a ``TreeAgg`` — so the rule
bodies stay mesh- and backend-agnostic while the engine keeps owning
the sharded machinery.

Registered via ``@register_tree_impl`` onto the dense rules declared in
``repro.core.gars``; the Bulyan family is attached by the resolver
(``repro.agg.registry``) since its bases are parametric.  The stateful
rules (buffered history, momentum centered-clip) live in
``repro.agg.buffered``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.agg.registry import TreeAgg, TreeContext, register_tree_impl
from repro.core import bulyan as bulyan_lib
from repro.core import gars

__all__ = ["bulyan_tree"]


@register_tree_impl("average")
def _average_tree(ctx: TreeContext) -> TreeAgg:
    return TreeAgg([jnp.mean(l.astype(ctx.cdt), axis=0)
                    for l in ctx.leaves], ctx.uniform(), ctx.zeros())


@register_tree_impl("cwmed")
def _cwmed_tree(ctx: TreeContext) -> TreeAgg:
    return TreeAgg([jnp.median(l.astype(ctx.cdt), axis=0)
                    for l in ctx.leaves], ctx.uniform(), ctx.zeros())


@register_tree_impl("trimmed_mean")
def _trimmed_mean_tree(ctx: TreeContext) -> TreeAgg:
    agg = [jnp.mean(jnp.sort(l.astype(ctx.cdt), axis=0)[ctx.f:ctx.n - ctx.f],
                    axis=0) for l in ctx.leaves]
    return TreeAgg(agg, ctx.uniform(), ctx.zeros())


@register_tree_impl("krum")
def _krum_tree(ctx: TreeContext) -> TreeAgg:
    scores = gars.krum_scores(ctx.dists(), jnp.ones((ctx.n,), bool),
                              ctx.f, ctx.n)
    i = jnp.argmin(scores)
    return TreeAgg(ctx.take_worker(i), jax.nn.one_hot(i, ctx.n,
                                                      dtype=ctx.cdt), scores)


@register_tree_impl("geomed")
def _geomed_tree(ctx: TreeContext) -> TreeAgg:
    scores = gars.geomed_scores(ctx.dists(), jnp.ones((ctx.n,), bool))
    i = jnp.argmin(scores)
    return TreeAgg(ctx.take_worker(i), jax.nn.one_hot(i, ctx.n,
                                                      dtype=ctx.cdt), scores)


@register_tree_impl("multikrum")
def _multikrum_tree(ctx: TreeContext) -> TreeAgg:
    scores = gars.krum_scores(ctx.dists(), jnp.ones((ctx.n,), bool),
                              ctx.f, ctx.n)
    m = max(1, ctx.n - ctx.f - 2)
    _, top = jax.lax.top_k(-scores, m)
    selected = jnp.zeros((ctx.n,), ctx.cdt).at[top].set(1.0 / m)
    return TreeAgg(ctx.weighted_sum(selected), selected, scores)


@register_tree_impl("brute")
def _brute_tree(ctx: TreeContext) -> TreeAgg:
    n, f = ctx.n, ctx.f
    dist2 = ctx.dists()
    diam = gars.brute_subset_diameters(dist2, n, f)
    idx = jnp.asarray(gars._subsets(n, n - f))
    best = jnp.argmin(diam)
    chosen = idx[best]
    selected = jnp.zeros((n,), ctx.cdt).at[chosen].set(1.0 / (n - f))
    member = jnp.zeros((len(idx), n), bool).at[
        jnp.arange(len(idx))[:, None], idx].set(True)
    scores = jnp.min(jnp.where(member, diam[:, None], jnp.inf), axis=0)
    return TreeAgg(ctx.weighted_sum(selected), selected, scores)


def bulyan_tree(ctx: TreeContext, base: str = "krum") -> TreeAgg:
    """Distributed Bulyan(base) for the distance-only bases (krum/geomed).

    Phase 1 runs on the (n, n) distance matrix alone
    (``select_indices_from_dists``); phase 2 is the engine's windowed
    coordinate phase, applied per leaf so each leaf keeps its sharding.

    Args:
      ctx: the engine-prepared tree context.
      base: phase-1 base rule, ``"krum"`` or ``"geomed"`` (bound by the
        resolver when building ``bulyan-<base>`` composites).

    Returns:
      A ``TreeAgg`` whose ``selected`` marks the theta = n - 2f
      phase-1 picks with weight 1.0.
    """
    idx = bulyan_lib.select_indices_from_dists(ctx.dists(), ctx.f, base=base)
    agg = [ctx.coordinate_phase(jnp.take(l.astype(ctx.cdt), idx, axis=0),
                                ctx.f) for l in ctx.leaves]
    selected = jnp.zeros((ctx.n,), ctx.cdt).at[idx].set(1.0)
    return TreeAgg(agg, selected, ctx.zeros())
