"""``fused-<base>`` registry composites: GARs lowered onto the megakernel.

``repro.kernels.fused_agg`` executes distance accumulation, selection and
the coordinate phase of a base rule in one Pallas sweep.  This module
wraps that kernel in the registry's :class:`AggregatorRule` shape so the
fused lowering is just another name — ``resolve_rule("fused-krum")`` —
with the base rule's quorum, resilience flag and invariant contract, and
therefore flows through ``distributed_aggregate``, the audit roster and
the dryrun CLI without any API change.

Two entry points:

  * :func:`make_fused` builds the composite rule for one ``fused-<base>``
    name (called lazily from ``registry.resolve_rule``);
  * :func:`fused_name` maps an arbitrary GAR name onto its fused
    counterpart (``"krum" -> "fused-krum"``,
    ``"stale-krum" -> "stale-fused-krum"``) or ``None`` when the rule has
    no fused lowering — which is how ``distance_backend="fused"`` reroutes
    rules inside the engine while leaving e.g. ``brute`` untouched.

The dense path runs the megakernel on the flat ``(n, d)`` stack.  The
tree path mirrors the unfused composites: a single-leaf tree still takes
the megakernel, while multi-leaf trees reuse the context's distance
accumulation (whatever backend produced it), derive selection weights
once via ``fused_agg.select_weights``, and run the fused
select+coordinate pair kernel per leaf.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.agg.registry import AggregatorRule, TreeAgg, resolve_rule
from repro.core.types import AggResult
from repro.obs.trace import named_span
from repro.kernels.fused_agg import (FUSED_MODES, fused_aggregate,
                                     fused_coordinate, select_weights)

__all__ = ["FUSED_BASES", "fused_name", "make_fused"]

#: base GAR names with a fused lowering (== fused_agg.FUSED_MODES)
FUSED_BASES = FUSED_MODES

#: stateful wrapper prefixes fused_name recurses through, longest first
#: so "stale-exp-" is not mis-split as "stale-" + "exp-..."
_WRAPPER_PREFIXES = ("stale-exp-", "stale-inv-", "stale-", "buffered-",
                     "reputation-", "obs-")


def fused_name(gar: str) -> Optional[str]:
    """Map a GAR name to its fused counterpart, or ``None``.

    Args:
      gar: any registry-resolvable GAR name — a base rule, a
        ``stale-`` / ``buffered-`` composite, or an already-fused name
        (idempotent).

    Returns:
      The ``fused-``-prefixed name whose composite lowers the same rule
      onto the megakernel (wrapper prefixes are preserved:
      ``"stale-krum" -> "stale-fused-krum"``,
      ``"reputation-krum" -> "reputation-fused-krum"``), or ``None``
      when the base has no fused lowering (``brute``, ``average``,
      ``centered_clip``, ...).
    """
    if gar.startswith("fused-"):
        return gar
    for prefix in _WRAPPER_PREFIXES:
        if gar.startswith(prefix):
            inner = fused_name(gar[len(prefix):])
            return None if inner is None else prefix + inner
    return f"fused-{gar}" if gar in FUSED_BASES else None


def make_fused(name: str) -> AggregatorRule:
    """Build the ``fused-<base>`` composite rule.

    Args:
      name: full composite name, e.g. ``"fused-bulyan-krum"``.

    Returns:
      An :class:`AggregatorRule` with the base rule's quorum/resilience
      contract whose dense path is the megakernel and whose tree path is
      the select+coordinate pair kernel.

    Raises:
      KeyError: when the base has no fused lowering.
    """
    base = name[len("fused-"):]
    if base not in FUSED_BASES:
        raise KeyError(f"unknown GAR {name!r}: no fused lowering for "
                       f"{base!r}; have {sorted(FUSED_BASES)}")
    base_rule = resolve_rule(base)

    def dense_fn(grads: jnp.ndarray, f: int) -> AggResult:
        with named_span("kernel/fused"):
            agg, sel, scores = fused_aggregate(grads, f, mode=base)
        return AggResult(agg.astype(grads.dtype),
                         sel.astype(grads.dtype),
                         scores.astype(grads.dtype))

    def tree_fn(ctx) -> TreeAgg:
        leaves = ctx.leaves
        n, f = ctx.n, ctx.f
        if len(leaves) == 1:
            leaf = leaves[0]
            with named_span("kernel/fused"):
                agg, sel, scores = fused_aggregate(
                    leaf.reshape(n, -1), f, mode=base)
            return TreeAgg([agg.reshape(leaf.shape[1:]).astype(ctx.cdt)],
                           sel.astype(ctx.cdt), scores.astype(ctx.cdt))
        if base in ("cwmed", "trimmed_mean"):
            w, sel, scores = None, ctx.uniform(), ctx.zeros()
        else:
            w, sel, scores = select_weights(
                ctx.dists().astype(jnp.float32), n, f, base)
            sel = sel[0].astype(ctx.cdt)
            scores = scores[0].astype(ctx.cdt)
        grad = [fused_coordinate(leaf.reshape(n, -1), w, f, mode=base)
                .reshape(leaf.shape[1:]).astype(ctx.cdt)
                for leaf in leaves]
        return TreeAgg(grad, sel, scores)

    return AggregatorRule(
        name=name,
        min_n=base_rule.min_n,
        dense_fn=dense_fn,
        tree_fn=tree_fn,
        byzantine_resilient=base_rule.byzantine_resilient,
        invariants=base_rule.invariants,
        doc=(f"{base} lowered onto the fused Pallas megakernel "
             f"(repro.kernels.fused_agg): distance accumulation, "
             f"selection and coordinate phase in one sweep."),
    )
