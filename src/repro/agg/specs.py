"""The unified Byzantine-protocol spec and the shared quorum check.

``repro.training.trainer.ByzantineSpec`` (single-host flat path) and
``repro.dist.train.DistByzantineSpec`` (sharded path) used to be two
near-duplicate dataclasses with three diverging quorum error messages
(the third lived in ``repro.dist.robust._check_quorum``).  They are now
one spec type, :class:`AggSpec`, kept importable under both old names,
and one checker, :func:`check_quorum`, used by every layer.

All fields are keyword-only (every call site in the repo already was),
so the two historic field orders can no longer conflict.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.agg.registry import resolve_rule

__all__ = ["AggSpec", "check_quorum"]


@dataclasses.dataclass(frozen=True, kw_only=True)
class AggSpec:
    """Static configuration of the Byzantine training protocol.

    One spec drives both runtimes: the single-host trainer reads
    ``n_workers`` from the spec, while the sharded train step takes the
    worker count from the batch's leading axis at trace time and leaves
    ``n_workers`` unset.  ``f`` is both the number of injected Byzantine
    workers and the bound the aggregation rule defends against
    (``declared_f`` overrides the latter).

    Fields beyond the shared core:
      agg_dtype / distance_backend — the sharded path's accumulation
        dtype contract and (n, n)-distance implementation (see
        ``repro.dist.robust``); the flat path ignores them.
      history_window — sliding-window length of ``buffered-*`` rules.
      seed — PRNG seed for in-graph attack noise on the sharded path
        (and for the ``random`` async delay schedule).
      async_tau / async_schedule — the asynchronous runtime's bounded
        staleness: per-worker maximal slot age (an int for a homogeneous
        bound or a tuple of per-worker bounds — heterogeneous, and
        attacker-controllable in the sense that Byzantine workers ignore
        it) and the deterministic delay schedule (``"fixed"`` staggered
        round-robin | ``"random"`` bounded Bernoulli).  Only the async
        step builders read them (``repro.dist.async_train``,
        ``repro.training.trainer.make_async_byzantine_step``);
        ``async_tau=0`` makes the async step reproduce the synchronous
        one exactly.
      speculative_k / draft_replica — robust speculative decoding
        (serving only): ``speculative_k`` is the verify-block length
        (``0``/``1`` = the per-token path), ``draft_replica`` the
        ensemble row whose parameters drive the cheap draft model.  Only
        the serving engine and ``repro.serving.speculative`` read them;
        the acceptance rule always tests drafts against the *robustly
        aggregated* verifier distribution, never a single replica.
      rep_lr / rep_decay — the ``reputation-*`` score schedule
        (``repro.agg.reputation``): EMA rate and forgetting factor,
        forwarded to ``resolve_rule``; ``None`` takes the module
        defaults.  A *set* (truthy) ``rep_lr`` additionally switches on
        the staleness-adaptive step-size tail: the train steps multiply
        the aggregated update by ``step_size_multiplier(state)`` when
        the resolved rule carries reputation.  Other rules ignore both.
      aux_batch — optional ``(inputs, labels)`` auxiliary clean batch
        (ByGARS): when set and the rule carries reputation, the trainer
        scores worker agreement against the gradient of the loss on
        this batch instead of the emitted aggregate — the variant that
        stays sound under a colluding majority, which can own the
        aggregate itself.  Excluded from spec equality (it holds
        arrays).
      telemetry — when True every layer resolves the GAR through
        ``effective_gar`` (the ``obs-<gar>`` forensics wrapper,
        ``repro.obs``), so the carried ``AggState.obs`` ring records
        per-worker selection/suspicion diagnostics each step.  The data
        path is bitwise-identical either way; attacks keep targeting
        the raw ``gar`` name.
    """

    f: int
    n_workers: Optional[int] = None
    gar: str = "bulyan-krum"
    attack: str = "none"
    attack_kwargs: tuple = ()          # (("gamma", 10.0), ...)
    declared_f: Optional[int] = None   # f the master *assumes* (>= actual)
    agg_dtype: str = "native"          # native | float32 | bfloat16
    distance_backend: str = "auto"     # auto | xla | pallas | fused
    history_window: int = 4            # buffered-* window length
    seed: int = 0
    async_tau: "int | tuple" = 0       # bounded staleness (scalar or per-worker)
    async_schedule: str = "fixed"      # fixed | random
    speculative_k: int = 0             # verify-block length (0/1 = per-token)
    draft_replica: int = 0             # ensemble row the draft model reads
    rep_lr: Optional[float] = None     # reputation-* EMA rate (None=default)
    rep_decay: Optional[float] = None  # reputation-* forgetting factor
    telemetry: bool = False            # aggregate through obs-<gar>
    aux_batch: Any = dataclasses.field(default=None, compare=False)

    @property
    def n_honest(self) -> int:
        """Honest worker count (requires ``n_workers``)."""
        if self.n_workers is None:
            raise ValueError("n_honest needs n_workers set on the spec")
        return self.n_workers - self.f

    @property
    def f_declared(self) -> int:
        """The bound the master aggregates with (defaults to ``f``)."""
        return self.declared_f if self.declared_f is not None else self.f

    @property
    def effective_gar(self) -> str:
        """The GAR name the runtime actually aggregates with.

        ``gar`` itself normally; with ``telemetry=True`` it is the
        idempotent ``obs-<gar>`` forensics wrapper (``repro.obs``),
        whose data path is bitwise the base rule's.  Attack plumbing
        keeps reading the raw ``gar`` — the attacker targets the
        defense, not its instrumentation.
        """
        if not self.telemetry:
            return self.gar
        from repro.obs.forensics import obs_name
        return obs_name(self.gar)

    def rule(self):
        """Resolve this spec's GAR through the registry.

        Args:
          (none) — reads ``effective_gar`` (``gar``, or its ``obs-``
          wrapper under ``telemetry=True``), ``history_window`` and the
          ``rep_lr`` / ``rep_decay`` reputation schedule.

        Returns:
          The resolved ``AggregatorRule``.
        """
        return resolve_rule(self.effective_gar,
                            history_window=self.history_window,
                            rep_lr=self.rep_lr, rep_decay=self.rep_decay)

    def validate(self, n_workers: Optional[int] = None, *,
                 distributed: bool = False) -> None:
        """Quorum-check this spec (both historic call forms).

        Args:
          n_workers: worker count to check against.  ``None`` falls back
            to ``self.n_workers`` (the single-host form
            ``spec.validate()``); the sharded step builders pass the
            batch's worker axis at trace time instead (the historic
            ``DistByzantineSpec.validate`` form).
          distributed: when True, additionally require the rule to have
            a distributed (tree) implementation — e.g. ``bulyan-brute``
            is valid on the flat path but rejected here.  This used to
            be inferred from ``n_workers is not None``, which wrongly
            forced the tree requirement onto flat specs validated with
            an explicit worker count; the sharded step builders now opt
            in explicitly.

        Returns:
          None.  Raises ``KeyError`` for an unknown rule (or, with
          ``distributed=True``, a rule without a tree implementation)
          and ``ValueError`` for a quorum violation or a missing count.
        """
        n = self.n_workers if n_workers is None else n_workers
        if n is None:
            raise ValueError(
                "validate() needs n_workers — set it on the spec or pass "
                "it explicitly")
        check_quorum(self.effective_gar, n, self.f_declared,
                     distributed=distributed,
                     history_window=self.history_window)


def check_quorum(gar: str, n: int, f: int, *, distributed: bool = False,
                 history_window: Optional[int] = None) -> None:
    """The one quorum check every layer shares.

    Args:
      gar: rule name (resolved through the registry — raises ``KeyError``
        with the canonical "unknown GAR" message for unknown names).
      n: worker count.
      f: declared Byzantine bound.
      distributed: when True, additionally require a tree-path
        implementation (e.g. distributed Bulyan only supports the
        distance-only bases krum/geomed), raising ``KeyError`` like the
        old ``dist.robust._check_quorum`` did.
      history_window: forwarded to ``resolve_rule`` for buffered rules.

    Returns:
      None.  Raises ``ValueError`` as ``"{gar} requires n >= {need} for
      f={f}, got n={n}"`` when the quorum is violated — the single
      message all three layers now agree on.
    """
    rule = resolve_rule(gar, history_window=history_window)
    if distributed and rule.tree_fn is None:
        if gar.startswith("bulyan") or "-bulyan" in gar:
            raise KeyError(
                f"distributed bulyan needs a distance-only base "
                f"(krum/geomed), got {gar!r}")
        raise KeyError(f"{gar!r} has no distributed (tree) implementation")
    need = rule.min_n(f)
    if n < need:
        raise ValueError(
            f"{gar} requires n >= {need} for f={f}, got n={n}")
