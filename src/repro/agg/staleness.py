"""Staleness-aware aggregation: the ``stale-<base>`` family.

In the bounded-staleness asynchronous regime (Alistarh et al. 2018's
lock-free setting; Jin et al.'s big-data Byzantine SGD), the master
aggregates whatever the ``GradientBus`` holds: worker w's slot gradient
was computed ``tau_w`` steps ago against older parameters.  Stale honest
gradients drift away from the current honest mean, which *widens* the
leeway the paper's attack exploits — a slow-drift poisoner is
indistinguishable from a slow honest worker.  The classical mitigation is
staleness weighting: discount each worker by how old its contribution is
before running any robust rule.

``stale-<base>`` wraps **any** registered base rule through the unchanged
registry (no per-rule forks): it reads per-worker staleness
``s_w = state.step - state.bus.versions[w]`` from the carried
:class:`~repro.agg.state.AggState`, computes weights

* ``inv`` (default): ``w = 1 / (1 + s)``;
* ``exp``: ``w = exp(-lam * (s - min(s)))`` (shifted by the freshest
  worker so weights never underflow collectively);

normalizes them by the freshest worker (``w / max(w)``, so the scale is
in ``(0, 1]`` and never *amplifies* anyone — a uniformly-fresh or
uniformly-stale committee gets scale exactly 1 and a ``stale-*`` rule
run synchronously is bit-identical to its base), and reweights the
worker stack before handing it to the base rule's dense/tree
implementation.  Stateful bases
(``buffered-*``, ``centered_clip_momentum``) compose: the same
``AggState`` carries both the bus and the base's buffers.

Name grammar: ``stale-<base>`` (inv weights), ``stale-inv-<base>``,
``stale-exp-<base>`` — e.g. ``stale-bulyan-krum``, ``stale-exp-cwmed``,
``stale-buffered-krum``.  Resolved and cached by
``repro.agg.registry.resolve_rule`` like the other composite families.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.agg.registry import AggregatorRule
from repro.agg.state import AggState

__all__ = ["DEFAULT_STALE_LAMBDA", "make_stale", "stale_scale",
           "stale_weights"]

#: decay rate of the ``exp`` staleness-weight schedule
DEFAULT_STALE_LAMBDA = 0.5


def stale_weights(staleness: jnp.ndarray, weight: str = "inv",
                  lam: float = DEFAULT_STALE_LAMBDA) -> jnp.ndarray:
    """Per-worker staleness weights (fresh = 1, monotone decreasing).

    Args:
      staleness: ``(n,)`` integer staleness values ``>= 0`` (steps since
        each worker's slot gradient was computed).
      weight: ``"inv"`` for ``1 / (1 + s)`` or ``"exp"`` for
        ``exp(-lam * (s - min(s)))`` — the exp schedule is shifted by
        the freshest worker so at least one weight is exactly 1 and the
        normalization in :func:`stale_scale` can never divide by an
        underflowed sum.
      lam: decay rate of the ``exp`` schedule (ignored by ``inv``).

    Returns:
      ``(n,)`` float32 weights in ``(0, 1]``.
    """
    s = staleness.astype(jnp.float32)
    if weight == "inv":
        return 1.0 / (1.0 + s)
    if weight == "exp":
        return jnp.exp(-lam * (s - jnp.min(s)))
    raise ValueError(
        f"staleness weight must be 'inv' or 'exp', got {weight!r}")


def stale_scale(state: AggState, weight: str = "inv",
                lam: float = DEFAULT_STALE_LAMBDA) -> jnp.ndarray:
    """Per-worker scale in ``(0, 1]`` read from a carried state.

    Staleness is ``state.step - state.bus.versions`` — the async step
    stamps ``versions[w]`` with the step each slot gradient was computed
    at and increments ``step`` once per aggregation, so at aggregation
    ``t`` the difference is exactly the slot age.  The weights are
    normalized by the freshest worker (``w / max(w)``): nobody is ever
    *amplified* — amplifying fresh workers destabilizes selection rules
    — and a uniformly-fresh (or uniformly-stale) committee gets scale
    exactly 1, so every base rule reproduces its synchronous output
    bitwise.

    The raw difference is clamped to ``>= 0`` before weighting: a bus
    whose version stamps outrun the carried ``step`` (a
    checkpoint-restored bus paired with a freshly zeroed state, or a
    restored ``step`` against freshly allocated slots) would otherwise
    produce negative staleness and ``inv`` weights above 1 — violating
    the never-amplify contract the paragraph above promises (and, for
    ``s <= -1``, a sign flip).  A worker from the future is treated as
    exactly fresh.

    Args:
      state: carried ``AggState`` with an allocated ``bus``.
      weight: staleness-weight schedule (see :func:`stale_weights`).
      lam: decay rate of the ``exp`` schedule.

    Returns:
      ``(n,)`` float32 scale ``w / max(w)`` (n = ``len(bus.versions)``).
    """
    staleness = jnp.maximum(state.step - state.bus.versions, 0)
    w = stale_weights(staleness, weight, lam)
    return w / jnp.max(w)


def make_stale(name: str, base: AggregatorRule, weight: str = "inv",
               lam: float = DEFAULT_STALE_LAMBDA) -> AggregatorRule:
    """Build the ``stale-<base>`` composite around any registered rule.

    The composite is stateful with ``"bus"`` prepended to the base's
    ``state_fields``: it reads staleness from the carried bus metadata,
    scales the worker stack by :func:`stale_scale`, and delegates to the
    base rule — the base's own dense/tree implementations run unchanged
    on the reweighted stack (a stateful base additionally threads the
    same ``AggState`` and owns the ``step`` increment).

    Args:
      name: composite registry name (``"stale[-inv|-exp]-<base>"``).
      base: the resolved base rule; its tree implementation is wrapped
        only when it has one.
      weight: staleness-weight schedule (see :func:`stale_weights`).
      lam: decay rate of the ``exp`` schedule.

    Returns:
      A stateful :class:`AggregatorRule` with the base's quorum.
    """
    state_fields: Tuple[str, ...] = (
        ("bus",) + tuple(f for f in base.state_fields if f != "bus"))

    def dense(grads, f, state):
        scale = stale_scale(state, weight, lam).astype(grads.dtype)
        scaled = grads * scale[:, None]
        if base.stateful:
            res, state = base.dense_fn(scaled, f, state)
        else:
            res = base.dense_fn(scaled, f)
            state = state._replace(step=state.step + 1)
        return res, state

    tree_fn = None
    if base.tree_fn is not None:
        def tree_fn(ctx, state):
            scale = stale_scale(state, weight, lam).astype(ctx.cdt)
            scaled = [l.astype(ctx.cdt)
                      * scale.reshape((ctx.n,) + (1,) * (l.ndim - 1))
                      for l in ctx.leaves]
            sctx = ctx.with_leaves(scaled)
            if base.stateful:
                out, state = base.tree_fn(sctx, state)
            else:
                out = base.tree_fn(sctx)
                state = state._replace(step=state.step + 1)
            return out, state

    return AggregatorRule(
        name=name, min_n=base.min_n, dense_fn=dense, tree_fn=tree_fn,
        byzantine_resilient=base.byzantine_resilient, stateful=True,
        state_fields=state_fields, history_window=base.history_window,
        # the base's invariants hold relative to the *reweighted* stack
        # it consumed (the audit recomputes the staleness scale)
        invariants=base.invariants,
        doc=f"staleness-weighted ({weight}) worker stack fed to "
            f"{base.name}")
