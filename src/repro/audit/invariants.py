"""The invariant catalogue: executable checks behind the self-audit.

Real Byzantine-tolerant systems rarely fail on the aggregation math —
they fail on *threshold and quorum logic*: a 2/3-threshold scheme that
silently requires full participation, an unvalidated share corrupting
reconstruction, a staleness bound nobody enforces.  This module turns
the repo's shared contracts into small executable checks, each returning
a list of human-readable violation strings (empty = holds), so the sweep
driver (``repro.audit.sweep``) can walk every registered rule x attack x
(n, f, tau, backend) corner and the CI audit job can fail on the first
regression.

The output invariants are *declared by the rules themselves* — each
:class:`~repro.agg.registry.AggregatorRule` carries an ``invariants``
tuple — and are asserted relative to the **effective stack** the rule
body consumed: ``stale-*`` composites reweight the workers before the
base rule runs, ``reputation-*`` composites blend each row toward the
trusted weighted mean, and ``buffered-*`` composites smooth them through
the window means, so :func:`effective_stack` (via
:func:`prewindow_stack`, which replays the per-step reweightings in
wrapper order) recomputes exactly that transformation from the carried
``AggState``.  See docs/audit.md for the full catalogue and the
rationale of each entry.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.agg.registry import AggregatorRule
from repro.agg.specs import check_quorum
from repro.agg.state import AggState

__all__ = ["check_convex", "check_finite", "check_hull",
           "check_quorum_contract", "check_rule_output", "check_trimmed",
           "effective_stack", "prewindow_stack"]

#: relative tolerance of the hull / convex checks (fp32 arithmetic)
_RTOL = 1e-4


def _tol(stack: np.ndarray) -> float:
    return _RTOL * max(float(np.max(np.abs(stack))), 1.0)


def prewindow_stack(rule: AggregatorRule, grads: jnp.ndarray,
                    state: Optional[AggState]) -> np.ndarray:
    """The per-step reweighted stack, *before* any history window-mean.

    Walks ``rule.state_fields`` **in order** — outermost wrapper first,
    the order composites prepend themselves in — and replays each
    stack-reweighting transformation from the *pre-call* state:

    * ``"reputation"`` (``reputation-*``): the reputation blend
      ``w_i * g_i + (1 - w_i) * g_w`` with weights
      ``reputation_scale(state)`` (see ``repro.agg.reputation``);
    * ``"bus"`` (``stale-*``): multiply by ``stale_scale(state)`` —
      recomputed here from the carried bus.

    ``"history"`` is deliberately *not* applied — the window mean needs
    the caller-tracked history of these per-step stacks, which is
    exactly what the sweep driver feeds back (one entry per step is this
    function's output; :func:`effective_stack` folds the mean).

    Args:
      rule: the resolved rule under audit.
      grads: raw ``(n, d)`` worker stack fed to ``rule.dense_fn``.
      state: the ``AggState`` passed *into* the call (``None`` for
        stateless rules).

    Returns:
      ``(n, d)`` float32 numpy stack after every per-step reweighting.
    """
    eff = np.asarray(grads, np.float32)
    if state is None:
        return eff
    for field in rule.state_fields:
        if field == "reputation":
            from repro.agg.reputation import blend_stack, reputation_scale
            w = reputation_scale(state)
            eff = np.asarray(blend_stack(jnp.asarray(eff), w), np.float32)
        elif field == "bus":
            from repro.agg.staleness import stale_scale
            weight = "exp" if "-exp-" in rule.name else "inv"
            scale = np.asarray(stale_scale(state, weight), np.float32)
            eff = eff * scale[:, None]
    return eff


def effective_stack(rule: AggregatorRule, grads: jnp.ndarray,
                    state: Optional[AggState],
                    history: Optional[Sequence[np.ndarray]] = None
                    ) -> np.ndarray:
    """The ``(n, d)`` stack the rule body actually aggregated.

    Composites transform the raw worker stack before their base rule
    sees it; the declared output invariants hold relative to the
    transformed stack.  This helper replays the transformation from the
    *pre-call* state, independently of the rule code it audits: first
    the per-step reweightings of :func:`prewindow_stack` (reputation
    blend, staleness scale — applied in ``state_fields`` order,
    outermost wrapper first), then for ``buffered-*`` (``"history"``)
    the per-worker window means over the caller-tracked ``history`` of
    (already reweighted) stacks.

    Args:
      rule: the resolved rule under audit.
      grads: raw ``(n, d)`` worker stack fed to ``rule.dense_fn``.
      state: the ``AggState`` passed *into* the call (``None`` for
        stateless rules).
      history: for history-buffered rules, the effective inputs of the
        last calls **including this one**, oldest first (the sweep
        driver tracks them — each entry a :func:`prewindow_stack`
        output; at most ``rule.history_window`` entries are used).
        ``None`` treats this as the first step.

    Returns:
      ``(n, d)`` float32 numpy stack the invariants are checked against.
    """
    eff = prewindow_stack(rule, grads, state)
    if "history" in rule.state_fields:
        w = rule.history_window or 1
        window = list(history or [])[-w:] or [eff]
        eff = np.mean(np.stack(window, axis=0), axis=0)
    return eff


def check_finite(agg: jnp.ndarray, label: str = "") -> List[str]:
    """No NaN/inf in the aggregate — the universal invariant.

    Args:
      agg: ``(d,)`` aggregated gradient.
      label: case label prefixed to any violation.

    Returns:
      List of violation strings (empty when every entry is finite).
    """
    a = np.asarray(agg, np.float32)
    if np.isfinite(a).all():
        return []
    return [f"{label}: aggregate contains NaN/inf "
            f"({int((~np.isfinite(a)).sum())} coords)"]


def check_hull(agg: jnp.ndarray, stack: np.ndarray,
               label: str = "") -> List[str]:
    """Per-coordinate convex-hull membership.

    Every declared-``"hull"`` rule promises its output coordinate lies
    within ``[min_w, max_w]`` of the (effective) worker stack — the
    basic "the master never invents a value no worker proposed" contract
    an aggregation bug (or a silently weakened rule) breaks first.

    Args:
      agg: ``(d,)`` aggregate.
      stack: ``(n, d)`` effective worker stack.
      label: case label for violations.

    Returns:
      Violations (empty when the aggregate is inside the hull + tol).
    """
    a = np.asarray(agg, np.float32)
    lo, hi = stack.min(axis=0), stack.max(axis=0)
    tol = _tol(stack)
    bad = (a < lo - tol) | (a > hi + tol)
    if not bad.any():
        return []
    i = int(np.argmax(bad))
    return [f"{label}: aggregate leaves the worker hull at coord {i}: "
            f"{a[i]:.6g} not in [{lo[i]:.6g}, {hi[i]:.6g}] "
            f"({int(bad.sum())} coords total)"]


def check_trimmed(agg: jnp.ndarray, stack: np.ndarray, f: int,
                  label: str = "") -> List[str]:
    """Per-coordinate f-trimmed-hull membership.

    Coordinate-wise rules (``cwmed``, ``trimmed_mean``) promise more
    than the hull: the output lies within ``[sorted[f], sorted[n-1-f]]``
    per coordinate — the f most extreme values on either side can never
    drag the aggregate, which is exactly the paper's coordinate-phase
    argument.

    Args:
      agg: ``(d,)`` aggregate.
      stack: ``(n, d)`` effective worker stack.
      f: Byzantine bound used by the call.
      label: case label for violations.

    Returns:
      Violations (empty when inside the trimmed range + tol).
    """
    n = stack.shape[0]
    if n <= 2 * f:
        return [f"{label}: trimmed check needs n > 2f (n={n}, f={f})"]
    a = np.asarray(agg, np.float32)
    s = np.sort(stack, axis=0)
    lo, hi = s[f], s[n - 1 - f]
    tol = _tol(stack)
    bad = (a < lo - tol) | (a > hi + tol)
    if not bad.any():
        return []
    i = int(np.argmax(bad))
    return [f"{label}: aggregate leaves the f-trimmed hull at coord {i}: "
            f"{a[i]:.6g} not in [{lo[i]:.6g}, {hi[i]:.6g}] "
            f"({int(bad.sum())} coords total)"]


def check_convex(gradient: jnp.ndarray, selected: jnp.ndarray,
                 stack: np.ndarray, label: str = "") -> List[str]:
    """``selected`` is a valid convex-combination certificate.

    Declared-``"convex"`` rules (the linear selection family: average,
    krum, geomed, multikrum, brute) report per-worker weights that must
    be nonnegative, sum to 1, and *reproduce the aggregate exactly*:
    ``gradient == selected @ stack``.  A rule whose certificate and
    output disagree is lying about who it selected — the diagnostic
    every attack evaluation in the repo trusts (``byz_weight``).

    Args:
      gradient: ``(d,)`` aggregate.
      selected: ``(n,)`` reported worker weights.
      stack: ``(n, d)`` effective worker stack.
      label: case label for violations.

    Returns:
      Violations (empty when the certificate checks out).
    """
    out: List[str] = []
    w = np.asarray(selected, np.float32)
    if (w < -1e-6).any():
        out.append(f"{label}: negative selection weight "
                   f"(min {float(w.min()):.3g})")
    if abs(float(w.sum()) - 1.0) > 1e-4:
        out.append(f"{label}: selection weights sum to "
                   f"{float(w.sum()):.6g}, not 1")
    recon = w @ stack
    err = float(np.max(np.abs(recon - np.asarray(gradient, np.float32))))
    if err > _tol(stack):
        out.append(f"{label}: selected @ stack differs from the "
                   f"aggregate by {err:.3g}")
    return out


def check_quorum_contract(gar: str, f: int,
                          history_window: Optional[int] = None
                          ) -> List[str]:
    """The quorum gate raises the one canonical message — and only then.

    For the rule's declared ``min_n(f)``:

    * ``n = min_n - 1`` must raise ``ValueError`` with *exactly* the
      shared message ``"{gar} requires n >= {need} for f={f}, got
      n={n}"`` (three layers used to carry three diverging messages;
      drift here means a caller matching on the message breaks);
    * ``n = min_n`` must pass.

    Args:
      gar: any rule name the resolver accepts.
      f: Byzantine bound to probe.
      history_window: forwarded to the resolver for buffered rules.

    Returns:
      Violations (empty when both sides of the threshold behave).
    """
    from repro.agg.registry import resolve_rule
    out: List[str] = []
    need = resolve_rule(gar, history_window=history_window).min_n(f)
    short = need - 1
    want = f"{gar} requires n >= {need} for f={f}, got n={short}"
    try:
        check_quorum(gar, short, f, history_window=history_window)
        out.append(f"{gar}: quorum violation n={short} < {need} "
                   f"(f={f}) not rejected")
    except ValueError as e:
        if str(e) != want:
            out.append(f"{gar}: non-canonical quorum message {e!r} "
                       f"(want {want!r})")
    except Exception as e:  # wrong exception type
        out.append(f"{gar}: quorum violation raised {type(e).__name__}, "
                   f"not ValueError")
    try:
        check_quorum(gar, need, f, history_window=history_window)
    except Exception as e:
        out.append(f"{gar}: minimal quorum n={need} (f={f}) wrongly "
                   f"rejected: {e}")
    return out


def check_rule_output(rule: AggregatorRule, gradient: jnp.ndarray,
                      selected: jnp.ndarray, stack: np.ndarray, f: int,
                      label: str = "") -> List[str]:
    """Dispatch every invariant the rule declares against one output.

    Args:
      rule: the resolved rule (its ``invariants`` tuple drives the
        dispatch).
      gradient: ``(d,)`` aggregate the rule returned.
      selected: ``(n,)`` reported selection weights.
      stack: the *effective* ``(n, d)`` stack (:func:`effective_stack`).
      f: Byzantine bound of the call.
      label: case label for violations.

    Returns:
      Concatenated violations of every declared check.
    """
    out: List[str] = []
    if "finite" in rule.invariants:
        out += check_finite(gradient, label)
    if "hull" in rule.invariants:
        out += check_hull(gradient, stack, label)
    if "trimmed" in rule.invariants:
        out += check_trimmed(gradient, stack, f, label)
    if "convex" in rule.invariants:
        out += check_convex(gradient, selected, stack, label)
    w = np.asarray(selected, np.float32)
    if (w < -1e-6).any():  # universal, even without "convex"
        out.append(f"{label}: negative selection weight "
                   f"(min {float(w.min()):.3g})")
    return out
