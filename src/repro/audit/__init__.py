"""Adversarial self-audit: corner sweeps + empirical leeway certification.

Two harnesses keep the repo's Byzantine-resilience claims honest:

* ``repro.audit.sweep`` — a property-based corner sweep walking every
  registered aggregation rule (base rules, ``bulyan-*`` / ``buffered-*``
  / ``stale-*`` composites, ``centered_clip_momentum``) against every
  registered attack over a (n, f, tau, schedule) grid, asserting the
  shared contracts: declared output invariants (``repro.audit
  .invariants``), the canonical quorum error message, bitwise
  base-equality of uniformly-stale composites, the bounded-staleness
  delivery guarantee, and the kernels' fp32-accumulation contract under
  bf16 inputs.
* ``repro.audit.leeway`` — the empirical leeway meter: measures each
  rule's ε-poisoning margin as model dimension grows and certifies the
  paper's two scaling laws (Krum-family leeway Omega(sqrt(d)), Bulyan's
  relative margin O(1/sqrt(d))) against slope windows and a checked-in
  JSON baseline artifact.

Both are CLIs (``python -m repro.audit.sweep`` / ``...audit.leeway``;
``scripts/run_audit.py`` chains them) and both *collect* violations
instead of raising, so one run reports every broken corner.  The CI
``audit`` job runs the quick grid on every push; docs/audit.md holds
the invariant catalogue and the measurement methodology.
"""
from repro.audit import invariants, leeway, sweep
from repro.audit.invariants import (check_quorum_contract,
                                    check_rule_output, effective_stack)
from repro.audit.leeway import certify, measure_leeway
from repro.audit.sweep import (AuditReport, SweepConfig, audit_roster,
                               run_sweep)

__all__ = ["AuditReport", "SweepConfig", "audit_roster", "certify",
           "check_quorum_contract", "check_rule_output",
           "effective_stack", "invariants", "leeway", "measure_leeway",
           "run_sweep", "sweep"]
