"""The adversarial corner sweep: every rule x attack x (n, f, tau) grid.

One driver walks **every** rule the registry resolves — the paper's base
rules, the ``bulyan-*`` / ``buffered-*`` / ``stale-*`` / ``fused-*`` /
``reputation-*`` composite families, ``centered_clip_momentum`` —
against every registered attack over a grid of worker counts, Byzantine
bounds, staleness patterns and delay schedules, and asserts the shared
contracts at each corner:

* **output invariants** — each rule's declared ``invariants`` tuple,
  checked against the effective stack it consumed
  (``repro.audit.invariants``);
* **quorum contract** — below ``min_n(f)`` every resolvable name raises
  the one canonical ``check_quorum`` ValueError; tree-less rules raise
  the canonical KeyError only under ``distributed=True``;
* **identity contract** — a ``stale-*`` composite over a uniformly
  stale (or uniformly fresh, or clock-skewed *negative*-staleness)
  committee is **bitwise** equal to its base rule;
* **staleness bound** — simulated delivery under every (tau, schedule)
  corner keeps ``staleness_excess`` at zero, and ``tau = 0`` delivers
  everyone every step;
* **arbitrary-f regime** — at ``f >= n/2`` every quorum-bound roster
  rule must *refuse to run* with the one canonical quorum message,
  while every ``reputation-*`` composite (whose ``min_n`` is constant
  in f) runs, emits a finite aggregate, and keeps its reputation
  weights inside ``[0, 1]``;
* **fp32 accumulation** — the Pallas kernels match their fp32 oracles
  on bf16 inputs (``repro.kernels.probes``, the fused megakernel
  included), and the sharded engine's bf16 tree path — under the
  ``xla`` *and* ``fused`` distance backends — agrees with the fp32
  flat reference while preserving leaf dtypes;
* **speculative serving** — per-position aggregation of synthetic
  ``(n, B, k, vocab)`` verifier-logit stacks satisfies each rule's
  declared invariants (the convex-hull contract on verifier logits),
  and the ``repro.serving.speculative.accept_block`` acceptance rule
  only ever emits tokens in the aggregate's support — a colluding
  draft yields exactly the aggregate's own argmax stream.

Violations are collected (not raised), so one run reports every broken
corner.  CLI: ``python -m repro.audit.sweep [--quick]`` exits non-zero
on any violation — the CI audit job's first gate.  Methodology notes in
docs/audit.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.registry import resolve_rule, rule_names
from repro.agg.state import init_state
from repro.audit.invariants import (check_finite, check_quorum_contract,
                                    check_rule_output, effective_stack,
                                    prewindow_stack)
from repro.core.attacks import get_attack

__all__ = ["AuditReport", "SweepConfig", "audit_roster", "main",
           "run_sweep"]

#: attacks whose submissions depend on their own previous ones
_DELAY_ATTACKS = ("stale_replay", "slow_drift")

#: per-attack keyword arguments used by the sweep (the omniscient
#: attacks use the paper's closed-form gamma — one cheap pass per call)
_ATTACK_KW: Dict[str, dict] = {
    "omniscient_lp": {"gamma": "closed", "margin": 1.0},
    "omniscient_linf": {"gamma": "closed", "direction": "anti"},
    "ipm": {"eps": 0.7},
    "stale_replay": {"scale": -1.5},
    "slow_drift": {"eps": 0.8},
}


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Static shape of one corner sweep.

    Args:
      d: coordinate count of the synthetic stacks (small on purpose —
        the contracts are dimension-free; the leeway meter owns the
        d-scaling story).
      fs: Byzantine bounds to probe.
      extra_n: worker-count offsets above each rule's ``min_n(f)``.
      attacks: attack names (``"none"`` = all-honest committee).
      steps: aggregation steps per stateful case (staleness patterns
        and history windows need a few steps to become non-trivial).
      taus: staleness bounds of the delivery simulation — ints and/or
        per-worker tuples.
      schedules: delay schedules of the delivery simulation.
      quorum_fs: Byzantine bounds of the quorum-contract section.
      seed: base PRNG seed (folded per case — the sweep is
        deterministic end to end).
    """

    d: int = 16
    fs: Tuple[int, ...] = (1, 2)
    extra_n: Tuple[int, ...] = (0, 2)
    attacks: Tuple[str, ...] = ("none", "omniscient_lp", "omniscient_linf",
                                "alie", "ipm", "signflip", "random",
                                "zero", "mimic", "stale_replay",
                                "slow_drift")
    steps: int = 3
    taus: Tuple = (0, 2, (0, 1, 3, 0, 2, 1, 3))
    schedules: Tuple[str, ...] = ("fixed", "random")
    quorum_fs: Tuple[int, ...] = (1, 2, 3)
    seed: int = 0


#: the CI-speed variant: one (n, f) corner, the attack families that
#: exercise distinct code paths, two steps
QUICK = SweepConfig(fs=(1,), extra_n=(2,),
                    attacks=("none", "omniscient_lp", "alie", "signflip",
                             "stale_replay"),
                    steps=2, taus=(0, 2), quorum_fs=(1, 2))


@dataclasses.dataclass
class AuditReport:
    """Outcome of one sweep: per-section case counts and violations.

    Args:
      cases: total corners evaluated.
      violations: every violation string collected across sections.
      sections: section name -> (cases, violations) counts.
    """

    cases: int = 0
    violations: List[str] = dataclasses.field(default_factory=list)
    sections: Dict[str, Tuple[int, int]] = dataclasses.field(
        default_factory=dict)

    def ok(self) -> bool:
        """True when no corner violated any contract.

        Args:
          (none).

        Returns:
          ``not self.violations``.
        """
        return not self.violations

    def add(self, section: str, cases: int,
            violations: Sequence[str]) -> None:
        """Fold one section's outcome into the report.

        Args:
          section: section name.
          cases: corners the section evaluated.
          violations: violations the section collected.

        Returns:
          None.
        """
        self.cases += cases
        self.violations.extend(violations)
        got = self.sections.get(section, (0, 0))
        self.sections[section] = (got[0] + cases,
                                  got[1] + len(violations))


def audit_roster() -> List[str]:
    """Every rule family the sweep audits, composites included.

    Args:
      (none).

    Returns:
      Sorted rule names: all statically registered rules plus one or
      more representatives of each composite family (``bulyan-*``,
      ``buffered-*``, ``stale-*``, ``stale-exp-*``, ``fused-*``,
      ``reputation-*``, ``obs-*`` and their nestings) — every name
      resolves through
      ``repro.agg.resolve_rule``.  The speculative serving section
      audits the roster's serving-capable subset (stateless rules with
      a tree path — what ``aggregate_logits`` can drive) as robust
      verifiers of the speculative decode mode; the arbitrary-f section
      splits the roster into quorum-bound rules (must refuse at
      ``f >= n/2``) and ``reputation-*`` composites (must run there —
      their declared ``invariants`` hold relative to the blended stack,
      see ``repro.audit.invariants.prewindow_stack``).
    """
    from repro.agg.fused import FUSED_BASES
    bases = rule_names()
    roster = list(bases)
    roster += ["bulyan-krum", "bulyan-geomed"]
    roster += ["buffered-cwmed", "buffered-krum", "buffered-trimmed_mean",
               "buffered-bulyan-krum"]
    roster += [f"stale-{b}" for b in bases]
    roster += ["stale-bulyan-krum", "stale-buffered-cwmed",
               "stale-exp-krum", "stale-exp-cwmed"]
    roster += [f"fused-{b}" for b in FUSED_BASES]
    roster += ["stale-fused-krum"]
    roster += [f"reputation-{b}" for b in bases]
    roster += ["reputation-bulyan-krum", "reputation-buffered-cwmed",
               "reputation-stale-krum", "stale-reputation-krum",
               "reputation-fused-krum"]
    roster += ["obs-krum", "obs-cwmed", "obs-bulyan-krum",
               "obs-stale-krum", "obs-reputation-krum"]
    return sorted(roster)


def _stale_pattern(n: int, s: int) -> np.ndarray:
    """Deterministic non-uniform staleness pattern for step ``s``."""
    return (np.arange(n) + s) % 3


def _case_key(base_key, *parts) -> jnp.ndarray:
    """Per-case PRNG key — crc32, not ``hash()`` (which is salted)."""
    tag = zlib.crc32("/".join(str(p) for p in parts).encode())
    return jax.random.fold_in(base_key, tag & 0x7FFFFFFF)


def _case_violations(name: str, attack: str, n: int, f: int,
                     cfg: SweepConfig, key) -> Tuple[int, List[str]]:
    """Run one (rule, attack, n, f) corner for ``cfg.steps`` steps."""
    rule = resolve_rule(name)
    attack_fn = None if attack == "none" else get_attack(attack)
    kw = dict(_ATTACK_KW.get(attack, {}))
    steps = cfg.steps if rule.stateful else 1
    out: List[str] = []
    state = (init_state(rule, jnp.zeros((n, cfg.d), jnp.float32))
             if rule.stateful else None)
    history: List[np.ndarray] = []
    prev = None
    for s in range(steps):
        k = jax.random.fold_in(key, s)
        honest = (jax.random.normal(k, (n - f, cfg.d), jnp.float32)
                  * 0.5 + 1.0)
        if attack_fn is None or f == 0:
            full = jnp.concatenate(
                [honest,
                 jax.random.normal(jax.random.fold_in(k, 1),
                                   (f, cfg.d), jnp.float32) * 0.5 + 1.0])
        else:
            if attack in _DELAY_ATTACKS:
                kw.update(prev=prev, step=s)
            byz = attack_fn(honest, f, jax.random.fold_in(k, 2), **kw)
            prev = byz
            full = jnp.concatenate([honest, byz])
        label = f"{name}/{attack}/n{n}/f{f}/s{s}"
        if rule.stateful:
            if "bus" in rule.state_fields:
                pat = _stale_pattern(n, s)
                state = state._replace(
                    step=jnp.asarray(s, jnp.int32),
                    bus=state.bus._replace(
                        versions=jnp.asarray(s - pat, jnp.int32)))
            else:
                state = state._replace(step=jnp.asarray(s, jnp.int32))
            res, new_state = rule.dense_fn(full, f, state)
        else:
            res = rule.dense_fn(full, f)
            new_state = state
        history.append(prewindow_stack(rule, full, state))
        eff = effective_stack(rule, full, state, history=history)
        out += check_rule_output(rule, res.gradient, res.selected, eff, f,
                                 label)
        state = new_state
    return steps, out


def _invariant_section(cfg: SweepConfig, report: AuditReport) -> None:
    """Rule x attack x (n, f) output-invariant grid."""
    key = jax.random.PRNGKey(cfg.seed)
    for name in audit_roster():
        rule = resolve_rule(name)
        for f in cfg.fs:
            for extra in cfg.extra_n:
                # at least two honest workers: average's quorum is 1,
                # but attacks need a non-degenerate honest committee
                n = max(rule.min_n(f), f + 2) + extra
                for attack in cfg.attacks:
                    k = _case_key(key, name, attack, n, f)
                    cases, violations = _case_violations(
                        name, attack, n, f, cfg, k)
                    report.add("invariants", cases, violations)


def _quorum_section(cfg: SweepConfig, report: AuditReport) -> None:
    """Canonical quorum errors on both sides of every threshold."""
    from repro.agg.specs import AggSpec, check_quorum
    for name in audit_roster():
        rule = resolve_rule(name)
        for f in cfg.quorum_fs:
            report.add("quorum", 1, check_quorum_contract(name, f))
        # distributed opt-in: tree-less rules raise the canonical
        # KeyError; rules with a tree implementation pass
        f = cfg.quorum_fs[0]
        n = rule.min_n(f)
        violations: List[str] = []
        try:
            check_quorum(name, n, f, distributed=True)
            if rule.tree_fn is None:
                violations.append(
                    f"{name}: tree-less rule accepted distributed=True")
        except KeyError as e:
            if rule.tree_fn is not None:
                violations.append(
                    f"{name}: has a tree implementation but "
                    f"distributed=True raised {e}")
        # the satellite-1 regression: a *flat* spec validated with an
        # explicit worker count must never demand a tree implementation
        try:
            AggSpec(f=f, gar=name).validate(n)
        except Exception as e:
            violations.append(
                f"{name}: flat validate(n={n}) wrongly raised "
                f"{type(e).__name__}: {e}")
        report.add("quorum", 2, violations)


def _identity_section(cfg: SweepConfig, report: AuditReport) -> None:
    """stale-* over a uniform committee is bitwise its base rule."""
    key = jax.random.PRNGKey(cfg.seed + 1)
    bases = [b for b in rule_names()
             if not resolve_rule(b).stateful] + ["bulyan-krum",
                                                 "fused-krum"]
    f = cfg.fs[0]
    # uniform staleness 0 / 3 and a clock-skewed *negative* staleness
    # (restored bus ahead of a zeroed step counter) — all must clamp or
    # normalize to scale exactly 1.0
    for uniform_s in (0, 3, -2):
        for weight in ("", "exp-"):
            for base in bases:
                base_rule = resolve_rule(base)
                n = base_rule.min_n(f) + 1
                k = _case_key(key, base, weight, uniform_s)
                full = (jax.random.normal(k, (n, cfg.d), jnp.float32)
                        * 0.5 + 1.0)
                stale_name = f"stale-{weight}{base}"
                rule = resolve_rule(stale_name)
                state = init_state(rule, full)
                step = 5
                state = state._replace(
                    step=jnp.asarray(step, jnp.int32),
                    bus=state.bus._replace(versions=jnp.full(
                        (n,), step - uniform_s, jnp.int32)))
                got, _ = rule.dense_fn(full, f, state)
                want = base_rule.dense_fn(full, f)
                violations: List[str] = []
                if not np.array_equal(np.asarray(got.gradient),
                                      np.asarray(want.gradient)):
                    err = float(np.max(np.abs(
                        np.asarray(got.gradient, np.float32)
                        - np.asarray(want.gradient, np.float32))))
                    violations.append(
                        f"{stale_name}: uniform staleness {uniform_s} "
                        f"not bitwise-equal to {base} (max abs diff "
                        f"{err:.3g})")
                if not np.array_equal(np.asarray(got.selected),
                                      np.asarray(want.selected)):
                    violations.append(
                        f"{stale_name}: uniform staleness {uniform_s} "
                        f"changes the selection vs {base}")
                report.add("identity", 1, violations)


def _staleness_section(cfg: SweepConfig, report: AuditReport) -> None:
    """Delivery simulation: the declared bound is never exceeded."""
    from repro.dist.async_train import (delivery_mask, init_bus,
                                        resolve_tau, staleness_excess,
                                        update_bus)
    n, steps = 7, 12
    key = jax.random.PRNGKey(cfg.seed + 2)
    for tau in cfg.taus:
        tau_arr = resolve_tau(tau, n)
        for schedule in cfg.schedules:
            violations: List[str] = []
            bus = init_bus(jnp.zeros((n, cfg.d), jnp.float32))
            for t in range(steps):
                grads = jax.random.normal(
                    jax.random.fold_in(key, t), (n, cfg.d), jnp.float32)
                mask = delivery_mask(t, bus.versions, tau_arr,
                                     schedule=schedule, seed=cfg.seed)
                if int(np.max(np.asarray(tau_arr))) == 0 \
                        and not bool(np.all(np.asarray(mask))):
                    violations.append(
                        f"tau=0/{schedule}: worker held back at step {t} "
                        f"(sync special case broken)")
                bus = update_bus(bus, grads, t, mask)
                excess = np.asarray(staleness_excess(bus, t, tau_arr))
                if (excess > 0).any():
                    violations.append(
                        f"tau={tau}/{schedule}: staleness bound exceeded "
                        f"at step {t} by {excess.tolist()}")
            report.add("staleness", steps, violations)


def _arbitrary_f_section(cfg: SweepConfig, report: AuditReport) -> None:
    """f >= n/2: quorum rules refuse canonically, reputation-* runs.

    The regime the paper's worker-count arithmetic cannot express: at
    ``f = n/2`` and ``f = 3n/4`` every roster rule whose ``min_n(f)``
    exceeds the committee must raise the one canonical quorum
    ``ValueError`` (silently running *weakened* is the failure mode this
    section exists to catch), while every ``reputation-*`` composite —
    ``min_n`` constant in f — must run, emit a finite aggregate, and
    keep its updated reputation weights inside ``[0, 1]``.
    """
    from repro.agg.specs import check_quorum
    key = jax.random.PRNGKey(cfg.seed + 5)
    n = 8
    for f in (n // 2, 3 * n // 4):
        for name in audit_roster():
            rule = resolve_rule(name)
            need = rule.min_n(f)
            violations: List[str] = []
            label = f"arbitrary-f/{name}/n{n}/f{f}"
            if name.startswith("reputation-") and need > n:
                violations.append(
                    f"{label}: reputation composite lost the arbitrary-f "
                    f"contract (min_n({f}) = {need} > {n})")
            if need > n:
                want = f"{name} requires n >= {need} for f={f}, got n={n}"
                try:
                    check_quorum(name, n, f)
                    violations.append(
                        f"{label}: quorum-bound rule ran at f >= n/2 "
                        f"instead of refusing (need n >= {need})")
                except ValueError as e:
                    if str(e) != want:
                        violations.append(
                            f"{label}: non-canonical refusal {e!r} "
                            f"(want {want!r})")
            else:
                k = _case_key(key, "arbitraryf", name, n, f)
                full = (jax.random.normal(k, (n, cfg.d), jnp.float32)
                        * 0.5 + 1.0)
                state = init_state(rule, full) if rule.stateful else None
                if rule.stateful:
                    res, new_state = rule.dense_fn(full, f, state)
                else:
                    res = rule.dense_fn(full, f)
                    new_state = None
                violations += check_finite(res.gradient, label)
                if name.startswith("reputation-"):
                    rep = np.asarray(new_state.reputation, np.float32)
                    if (rep < 0).any() or (rep > 1).any():
                        violations.append(
                            f"{label}: updated reputation outside [0, 1] "
                            f"(min {float(rep.min()):.3g}, max "
                            f"{float(rep.max()):.3g})")
            report.add("arbitrary-f", 1, violations)


def _fp32_section(cfg: SweepConfig, report: AuditReport) -> None:
    """bf16-input fp32-accumulation contract: kernels and tree path."""
    from repro.dist.robust import distributed_aggregate
    from repro.kernels.probes import (coord_fp32_contract_error,
                                      fused_fp32_contract_error,
                                      gram_fp32_contract_error)
    tol = 1e-4
    violations: List[str] = []
    for d, block_d in ((512, 256), (1536, 512)):
        err = gram_fp32_contract_error(n=8, d=d, block_d=block_d,
                                       seed=cfg.seed)
        if err > tol:
            violations.append(
                f"pairwise_gram bf16 d={d} block={block_d}: rel err "
                f"{err:.3g} > {tol} — fp32 accumulation broken?")
        err = coord_fp32_contract_error(theta=9, f=2, d=d,
                                        block_d=block_d, seed=cfg.seed)
        if err > tol:
            violations.append(
                f"bulyan_select bf16 d={d} block={block_d}: rel err "
                f"{err:.3g} > {tol} — fp32 accumulation broken?")
        for mode in ("bulyan-krum", "trimmed_mean"):
            err = fused_fp32_contract_error(n=11, f=2, d=d, mode=mode,
                                            block_d=block_d,
                                            seed=cfg.seed)
            if err > tol:
                violations.append(
                    f"fused_aggregate[{mode}] bf16 d={d} "
                    f"block={block_d}: rel err {err:.3g} > {tol} — "
                    f"fp32 accumulation broken?")
    report.add("fp32", 8, violations)

    # sharded engine: bf16 tree, default (fp32) accumulation — must
    # match the flat fp32 reference and keep the leaf dtype
    key = jax.random.PRNGKey(cfg.seed + 3)
    n, f = 11, 2  # bulyan quorum: 4f + 3
    leaves = {
        "w": jax.random.normal(key, (n, 24, 8)).astype(jnp.bfloat16),
        "b": jax.random.normal(jax.random.fold_in(key, 1),
                               (n, 40)).astype(jnp.bfloat16),
    }
    flat = jnp.concatenate(
        [leaves["b"].astype(jnp.float32).reshape(n, -1),
         leaves["w"].astype(jnp.float32).reshape(n, -1)], axis=1)
    for gar in ("krum", "cwmed", "bulyan-krum"):
        # "auto" is the historic xla-reference case; "fused" reroutes
        # the rule onto the megakernel composite — both must track the
        # flat fp32 reference and preserve leaf dtypes
        for backend in ("auto", "fused"):
            violations = []
            agg, _ = distributed_aggregate(leaves, f, gar,
                                           distance_backend=backend)
            got = jnp.concatenate(
                [agg["b"].astype(jnp.float32).reshape(-1),
                 agg["w"].astype(jnp.float32).reshape(-1)])
            want = resolve_rule(gar).dense_fn(flat, f).gradient
            scale = max(float(jnp.max(jnp.abs(want))), 1.0)
            err = float(jnp.max(jnp.abs(got - want))) / scale
            if err > 1e-2:  # bf16 output quantization, fp32 accumulation
                violations.append(
                    f"{gar}[{backend}]: bf16 tree path deviates from "
                    f"fp32 flat reference by rel {err:.3g}")
            for name, leaf in agg.items():
                if leaf.dtype != jnp.bfloat16:
                    violations.append(
                        f"{gar}[{backend}]: leaf {name!r} came back "
                        f"{leaf.dtype}, input dtype not preserved")
            report.add("fp32", 1, violations)


def _speculative_section(cfg: SweepConfig, report: AuditReport) -> None:
    """Robust speculative serving: acceptance + aggregation contracts.

    For every serving-capable roster rule (tree path required — the
    serving aggregation runs through ``aggregate_logits``) and every
    applicable attack, synthetic ``(n, B, k, vocab)`` verifier-logit
    stacks are aggregated per position exactly like
    ``make_robust_verify_step`` does, and two contracts are asserted:

    * **verifier aggregation invariants** — each position's aggregate
      satisfies the rule's declared invariants (convex-hull membership,
      trimming, finiteness) against the stack it consumed — the
      convex-hull contract on verifier logits;
    * **acceptance rule** — every token :func:`accept_block` emits
      carries an aggregated logit within ``margin`` of that position's
      maximum (accepted token survives the aggregate's support — never a
      single replica's), counts stay in ``[1, k]``, and a draft that
      copies the aggregate argmax is accepted in full while a colluding
      constant-token draft yields exactly the aggregate's own argmax
      stream.
    """
    from repro.dist.serve_robust import aggregate_logits
    from repro.serving.speculative import accept_block
    key = jax.random.PRNGKey(cfg.seed + 4)
    batch, k_block, vocab = 2, 4, cfg.d
    f = cfg.fs[0]
    roster = [name for name in audit_roster()
              if resolve_rule(name).tree_fn is not None
              and not resolve_rule(name).stateful]
    attacks = [a for a in cfg.attacks if a not in _DELAY_ATTACKS]
    for name in roster:
        rule = resolve_rule(name)
        n = max(rule.min_n(f), f + 2) + cfg.extra_n[-1]
        for attack in attacks:
            violations: List[str] = []
            ck = _case_key(key, "speculative", name, attack, n, f)
            honest = (jax.random.normal(
                ck, (n - f, batch, k_block, vocab), jnp.float32) * 0.5)
            if attack == "none" or f == 0:
                byz = jax.random.normal(
                    jax.random.fold_in(ck, 1),
                    (f, batch, k_block, vocab), jnp.float32) * 0.5
            else:
                flat = get_attack(attack)(
                    honest.reshape(n - f, -1), f,
                    jax.random.fold_in(ck, 2),
                    **_ATTACK_KW.get(attack, {}))
                byz = flat.reshape(f, batch, k_block, vocab)
            stack = jnp.concatenate([honest, byz])   # (n, B, k, V)
            aggs = []
            for j in range(k_block):
                agg, diag = aggregate_logits(stack[:, :, j, :], f, name)
                aggs.append(agg)
                label = f"speculative/{name}/{attack}/pos{j}"
                violations += check_rule_output(
                    rule, jnp.reshape(agg, (-1,)), diag.selected,
                    np.asarray(stack[:, :, j, :], np.float32
                               ).reshape(n, -1), f, label)
            agg_logits = jnp.stack(aggs, axis=1)     # (B, k, V)
            v = np.asarray(jnp.argmax(agg_logits, axis=-1))
            t0 = jnp.zeros((batch,), jnp.int32)
            # a draft that copies the aggregate argmax must be accepted
            # in full; a colluding constant-token draft must yield the
            # aggregate's own argmax stream (collusion costs throughput,
            # never correctness)
            blocks = {
                "clean": jnp.concatenate(
                    [t0[:, None], jnp.asarray(v[:, :k_block - 1])], axis=1),
                "colluding": jnp.concatenate(
                    [t0[:, None],
                     jnp.full((batch, k_block - 1), 3, jnp.int32)], axis=1),
            }
            anp = np.asarray(agg_logits, np.float32)
            for kind, block in blocks.items():
                emitted, count, _ = accept_block(block, agg_logits)
                emitted, count = np.asarray(emitted), np.asarray(count)
                label = f"speculative/{name}/{attack}/{kind}"
                if ((count < 1) | (count > k_block)).any():
                    violations.append(
                        f"{label}: emission count {count.tolist()} "
                        f"outside [1, {k_block}]")
                for b in range(batch):
                    for j in range(int(count[b])):
                        gap = float(anp[b, j].max()
                                    - anp[b, j, emitted[b, j]])
                        if gap > 1e-5:
                            violations.append(
                                f"{label}: emitted token at slot {b} "
                                f"pos {j} trails the aggregate max by "
                                f"{gap:.3g} — not in the aggregate's "
                                f"support")
                if kind == "clean" and (count != k_block).any():
                    violations.append(
                        f"{label}: argmax-copying draft not fully "
                        f"accepted (counts {count.tolist()})")
                if kind == "colluding":
                    for b in range(batch):
                        got = emitted[b, :count[b]].tolist()
                        want = v[b, :count[b]].tolist()
                        if got != want:
                            violations.append(
                                f"{label}: colluding draft changed the "
                                f"accepted stream {got} vs aggregate "
                                f"argmax {want}")
            report.add("speculative", k_block + 2, violations)


def run_sweep(cfg: Optional[SweepConfig] = None) -> AuditReport:
    """Run every section of the corner sweep.

    Args:
      cfg: sweep shape (``None`` = the full default grid; pass
        :data:`QUICK` for the CI-speed variant).

    Returns:
      The populated :class:`AuditReport` (violations collected, never
      raised).
    """
    cfg = cfg or SweepConfig()
    report = AuditReport()
    _quorum_section(cfg, report)
    _identity_section(cfg, report)
    _arbitrary_f_section(cfg, report)
    _staleness_section(cfg, report)
    _fp32_section(cfg, report)
    _invariant_section(cfg, report)
    _speculative_section(cfg, report)
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry: run the sweep and report violations.

    Args:
      argv: command-line arguments (``None`` = ``sys.argv[1:]``);
        ``--quick`` selects the CI grid, ``--seed`` reseeds the
        deterministic case PRNG.

    Returns:
      Process exit code — the number of violations (0 = all contracts
      hold).
    """
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI grid: one (n, f) corner per rule")
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed of the synthetic stacks")
    args = ap.parse_args(argv)
    cfg = dataclasses.replace(QUICK if args.quick else SweepConfig(),
                              seed=args.seed)
    report = run_sweep(cfg)
    for section, (cases, bad) in sorted(report.sections.items()):
        print(f"audit/{section}: {cases} cases, {bad} violations",
              flush=True)
    for v in report.violations:
        print(f"VIOLATION: {v}", flush=True)
    print(f"audit/total: {report.cases} cases, "
          f"{len(report.violations)} violations", flush=True)
    return len(report.violations)


if __name__ == "__main__":
    raise SystemExit(main())
