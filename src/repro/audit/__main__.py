"""``python -m repro.audit`` — run both audit gates back to back.

Subcommands delegate to the section CLIs:

* ``python -m repro.audit sweep [--quick] [--seed S]``
* ``python -m repro.audit leeway [--dims ...] [--baseline FILE] ...``

With no subcommand, the quick sweep and the default leeway
certification both run and the exit code is the total violation count
(what the CI audit job checks; ``scripts/run_audit.py`` wraps the same
entry with the checked-in baseline path).
"""
from __future__ import annotations

import sys

from repro.audit import leeway, sweep


def main(argv=None) -> int:
    """Dispatch to the sweep/leeway CLIs (or run both).

    Args:
      argv: command-line arguments (``None`` = ``sys.argv[1:]``); the
        first token may be ``sweep`` or ``leeway``, the rest is passed
        through to that CLI.

    Returns:
      Process exit code — the total number of violations.
    """
    args = list(sys.argv[1:] if argv is None else argv)
    if args and args[0] == "sweep":
        return sweep.main(args[1:])
    if args and args[0] == "leeway":
        return leeway.main(args[1:])
    return sweep.main(["--quick"] + args) + leeway.main(args)


if __name__ == "__main__":
    raise SystemExit(main())
