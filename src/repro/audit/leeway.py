"""The empirical leeway meter: ε-poisoning margins as d grows.

The paper's core quantitative claim is a pair of scaling laws.  The
attacker's *leeway* — the largest single-coordinate poison gamma_m a
selection rule still accepts — grows like Omega(sqrt(d)) for the
Krum/GeoMed family (§3.2/§B), so the aggregate a poisoned Krum emits
drifts from the honest mean by an amount that **grows** with model
dimension.  Bulyan's coordinate phase cuts that drift back to
O(sigma / sqrt(d)) *relative to the gradient's own scale*
(Proposition 2), so its relative margin **shrinks** as d grows.

This module measures both empirically: for each rule over a dimension
ladder it records

* ``margin_abs`` — max per-coordinate deviation of the rule's aggregate
  from the honest mean under the paper's omniscient attack (tuned by
  the exact in-graph gamma search against Krum, margin 0.95);
* ``margin_rel`` — the same deviation normalized by the l2 norm of the
  honest mean (which itself grows like sqrt(d)), i.e. the poisoning
  displacement in units of the signal the optimizer consumes;
* ``gamma`` — for the searchable selection rules, the measured gamma_m
  itself (the Omega(sqrt(d)) certificate).

and fits log-log slopes.  :func:`certify` gates the slopes against
per-rule expectations — Krum-family margins must *grow* (slope >=
0.35), Bulyan's relative margin must *shrink* (slope <= -0.25) — and
against a checked-in baseline artifact (ratio tolerances, not exact
equality: BLAS summation order differs across machines).  A weakened
rule — e.g. one that silently aggregates with ``f = 0`` — fails the
gate, which is exactly the regression the CI audit job exists to catch.

CLI: ``python -m repro.audit.leeway --out artifact.json`` writes the
JSON artifact, ``--baseline benchmarks/artifacts/leeway_baseline.json``
additionally gates against the checked-in baseline.  Methodology notes
in docs/audit.md; ``benchmarks/leeway_scaling.py`` renders the same
measurement as benchmark CSV rows.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.registry import resolve_rule
from repro.core.attacks import (find_gamma_max, make_selection_checker,
                                omniscient_lp)

__all__ = ["DEFAULT_DIMS", "DEFAULT_EXPECTATIONS", "DEFAULT_RULES",
           "certify", "main", "measure_leeway", "slope"]

#: dimension ladder of the default measurement (kept modest so the CI
#: gate stays fast; the nightly benchmark extends it)
DEFAULT_DIMS: Tuple[int, ...] = (64, 256, 1024)

#: rules the meter tracks by default — entries are either a rule name
#: or a ``(label, gar, f_used)`` triple (``f_used`` overrides the bound
#: the rule aggregates with: the weakened-rule injection path)
DEFAULT_RULES: Tuple[Union[str, Tuple[str, str, int]], ...] = (
    "average", "krum", "multikrum", "geomed", "cwmed", "bulyan-krum")

#: per-label slope expectations: (metric, lo, hi) — ``None`` bounds are
#: open.  Derived from §3.2/§B (sqrt(d) growth of the selection
#: leeway) and Proposition 2 (Bulyan's O(1/sqrt(d)) relative margin).
DEFAULT_EXPECTATIONS: Dict[str, Tuple[str, Optional[float],
                                      Optional[float]]] = {
    "average": ("abs", 0.35, None),     # carries the poison ~ sqrt(d)
    "krum": ("abs", 0.35, None),        # selects it ~ sqrt(d)
    "multikrum": ("abs", 0.35, None),
    "geomed": ("abs", 0.35, None),
    "cwmed": ("rel", None, -0.10),      # coordinate-wise: shrinks
                                        # (slowly at small d: the max-
                                        # coordinate statistic still
                                        # grows like sqrt(log d))
    "bulyan-krum": ("rel", None, -0.25),  # Proposition 2
}

#: ratio tolerance of the baseline gate (cross-machine BLAS variation
#: is well under this; a weakened rule blows through it)
BASELINE_RATIO = 3.0

#: selection rules whose gamma_m the exact search can measure
_GAMMA_RULES = ("krum", "geomed")


def slope(dims: Sequence[int], values: Sequence[float]) -> float:
    """Log-log slope of ``values`` against ``dims``.

    Args:
      dims: dimension ladder (positive, increasing).
      values: measured positive values, one per dimension.

    Returns:
      The least-squares slope of ``log(values)`` vs ``log(dims)`` —
      the empirical scaling exponent.
    """
    v = np.maximum(np.asarray(values, float), 1e-12)
    return float(np.polyfit(np.log(np.asarray(dims, float)),
                            np.log(v), 1)[0])


def _rule_entries(rules) -> List[Tuple[str, str, Optional[int]]]:
    out = []
    for r in rules:
        if isinstance(r, str):
            out.append((r, r, None))
        else:
            label, gar, f_used = r
            out.append((str(label), str(gar), int(f_used)))
    return out


def measure_leeway(rules=DEFAULT_RULES, dims: Sequence[int] = DEFAULT_DIMS,
                   n_h: int = 12, f: int = 3, seed: int = 11,
                   margin: float = 0.95) -> Dict:
    """Measure per-rule poisoning margins over a dimension ladder.

    At each d: ``n_h`` honest gradients ~ ``N(1, 0.5)`` (the benchmark
    family's shape), the paper's omniscient single-coordinate attack
    tuned by the exact gamma search against Krum at the given selection
    margin, then every rule aggregates the same poisoned stack and its
    deviation from the honest mean is recorded.

    Args:
      rules: rule names or ``(label, gar, f_used)`` triples —
        ``f_used`` is the bound passed to the rule (weakened-rule
        injection uses e.g. ``("bulyan-weak", "bulyan-krum", 0)``;
        quorum is still checked against the *honest* f).
      dims: dimension ladder.
      n_h: honest worker count.
      f: Byzantine worker count (and the default aggregation bound).
      seed: PRNG seed — the artifact is a pure function of the inputs.
      margin: fraction of the measured gamma_m the attacker submits
        (0.95 = just inside the selection boundary).

    Returns:
      JSON-ready report dict: config echo, per-rule ``margin_abs`` /
      ``margin_rel`` ladders with fitted ``slope_abs`` / ``slope_rel``,
      and the measured ``gamma`` ladders + slopes for the searchable
      selection rules.
    """
    entries = _rule_entries(rules)
    key = jax.random.PRNGKey(seed)
    per_rule: Dict[str, Dict] = {
        label: {"gar": gar, "f_used": f if f_used is None else f_used,
                "margin_abs": [], "margin_rel": []}
        for label, gar, f_used in entries}
    gammas: Dict[str, List[float]] = {r: [] for r in _GAMMA_RULES}
    for d in dims:
        honest = (jax.random.normal(jax.random.fold_in(key, d),
                                    (n_h, d)) * 0.5 + 1.0)
        e = jnp.zeros((d,)).at[0].set(1.0)
        for gname in _GAMMA_RULES:
            check = make_selection_checker(gname, f)
            gammas[gname].append(
                float(find_gamma_max(honest, f, e, check)))
        byz = omniscient_lp(honest, f, None, gar_name="krum",
                            margin=margin)
        full = jnp.concatenate([honest, byz])
        mean = jnp.mean(honest, axis=0)
        mean_norm = float(jnp.linalg.norm(mean))
        for label, gar, f_used in entries:
            rule = resolve_rule(gar)
            fu = f if f_used is None else f_used
            agg = rule.dense_fn(full, fu).gradient
            dev = float(jnp.max(jnp.abs(agg - mean)))
            per_rule[label]["margin_abs"].append(dev)
            per_rule[label]["margin_rel"].append(dev / mean_norm)
    for label in per_rule:
        per_rule[label]["slope_abs"] = slope(
            dims, per_rule[label]["margin_abs"])
        per_rule[label]["slope_rel"] = slope(
            dims, per_rule[label]["margin_rel"])
    return {
        "config": {"dims": list(dims), "n_h": n_h, "f": f, "seed": seed,
                   "margin": margin},
        "rules": per_rule,
        "gamma": {g: {"values": v, "slope": slope(dims, v)}
                  for g, v in gammas.items()},
    }


def certify(report: Dict, expectations: Optional[Dict] = None,
            baseline: Optional[Dict] = None) -> List[str]:
    """Gate a leeway report against the scaling laws and a baseline.

    Args:
      report: a :func:`measure_leeway` report.
      expectations: per-label ``(metric, lo, hi)`` slope windows
        (``None`` = :data:`DEFAULT_EXPECTATIONS`; labels absent from
        the map are not slope-gated).  ``metric`` is ``"abs"`` or
        ``"rel"``; ``lo`` / ``hi`` are inclusive bounds, ``None`` =
        open.
      baseline: a previously saved report to regress against: every
        shared (label, dim) margin must stay within a factor of
        :data:`BASELINE_RATIO` of the baseline value, and the gamma
        slopes within +-0.2.  ``None`` skips the comparison.

    Returns:
      List of violation strings — empty when the artifact certifies.
    """
    exp = DEFAULT_EXPECTATIONS if expectations is None else expectations
    out: List[str] = []
    for label, rec in report["rules"].items():
        if label not in exp:
            continue
        metric, lo, hi = exp[label]
        s = rec[f"slope_{metric}"]
        if lo is not None and s < lo:
            out.append(
                f"{label}: {metric} margin slope {s:.3f} < {lo} — "
                f"expected to grow with d")
        if hi is not None and s > hi:
            out.append(
                f"{label}: {metric} margin slope {s:.3f} > {hi} — "
                f"expected to shrink with d")
    for gname, rec in report.get("gamma", {}).items():
        s = rec["slope"]
        if not 0.3 <= s <= 0.7:
            out.append(
                f"gamma_{gname}: log-log slope {s:.3f} outside "
                f"[0.3, 0.7] — the Omega(sqrt(d)) leeway law broke")
    if baseline is not None:
        dims = report["config"]["dims"]
        bdims = baseline["config"]["dims"]
        shared = [d for d in dims if d in bdims]
        for label, rec in report["rules"].items():
            brec = baseline["rules"].get(label)
            if brec is None:
                continue
            for d in shared:
                got = rec["margin_abs"][dims.index(d)]
                want = brec["margin_abs"][bdims.index(d)]
                lo_b = want / BASELINE_RATIO
                hi_b = want * BASELINE_RATIO
                if not lo_b <= got <= hi_b or (want < 1e-9 < got):
                    out.append(
                        f"{label}@d={d}: margin_abs {got:.4g} outside "
                        f"[{lo_b:.4g}, {hi_b:.4g}] of baseline "
                        f"{want:.4g}")
        for gname, rec in report.get("gamma", {}).items():
            brec = baseline.get("gamma", {}).get(gname)
            if brec and abs(rec["slope"] - brec["slope"]) > 0.2:
                out.append(
                    f"gamma_{gname}: slope {rec['slope']:.3f} drifted "
                    f"more than 0.2 from baseline {brec['slope']:.3f}")
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry: measure, optionally write/gate the JSON artifact.

    Args:
      argv: command-line arguments (``None`` = ``sys.argv[1:]``);
        ``--dims``, ``--n-h``, ``--f``, ``--seed`` shape the
        measurement, ``--out`` writes the artifact, ``--baseline``
        additionally gates against a checked-in artifact.

    Returns:
      Process exit code — the number of certification violations.
    """
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--dims", type=int, nargs="+",
                    default=list(DEFAULT_DIMS))
    ap.add_argument("--n-h", type=int, default=12,
                    help="honest worker count")
    ap.add_argument("--f", type=int, default=3,
                    help="Byzantine worker count")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--out", type=str, default=None,
                    help="write the JSON artifact here")
    ap.add_argument("--baseline", type=str, default=None,
                    help="gate against this checked-in artifact")
    args = ap.parse_args(argv)
    report = measure_leeway(dims=tuple(args.dims), n_h=args.n_h,
                            f=args.f, seed=args.seed)
    baseline = None
    if args.baseline:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    violations = certify(report, baseline=baseline)
    for label, rec in sorted(report["rules"].items()):
        print(f"leeway/{label}: slope_abs={rec['slope_abs']:+.3f} "
              f"slope_rel={rec['slope_rel']:+.3f} "
              f"margin_abs={['%.3g' % m for m in rec['margin_abs']]}",
              flush=True)
    for gname, rec in sorted(report["gamma"].items()):
        print(f"leeway/gamma_{gname}: slope={rec['slope']:+.3f}",
              flush=True)
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"leeway: artifact written to {args.out}", flush=True)
    for v in violations:
        print(f"VIOLATION: {v}", flush=True)
    print(f"leeway: {len(violations)} violations", flush=True)
    return len(violations)


if __name__ == "__main__":
    raise SystemExit(main())
