"""Shared building blocks of the Pallas aggregation kernels.

Three kernels (``pairwise_gram``, ``bulyan_select``, ``coord_stats``)
and the fused megakernel (``fused_agg``) share the same primitives: the
interpret-mode resolution against the active jax backend, the unrolled
odd-even transposition sorting network, and the per-tile combine bodies
(Bulyan's beta-closest-to-median window, the coordinate-wise median and
f-trimmed mean).  They used to be duplicated — or imported sideways,
``coord_stats -> bulyan_select -> pairwise_gram`` — which made every new
kernel deepen the chain.  This module is the single home: kernels import
*down* into ``common`` only, never into each other.

Every helper is shape-polymorphic over "rows": a list of equally-shaped
arrays treated as axis 0 of a (rows, ...) stack.  Inside a kernel the
rows are ``(block_d,)`` lane vectors; the same code runs on full
``(d,)`` arrays under plain jit, which is what gives the fused kernel a
bitwise-comparable out-of-kernel reference path.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

__all__ = ["bulyan_window", "coord_median", "coord_trimmed_mean",
           "oe_sort_rows", "resolve_interpret"]


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve the ``interpret`` knob against the active jax backend.

    Args:
      interpret: ``True`` / ``False`` to force, ``None`` to pick the
        compiled kernel on TPU and the Pallas interpreter elsewhere
        (CPU CI containers, GPU hosts).

    Returns:
      bool: the concrete interpret flag to hand to ``pl.pallas_call``.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def oe_sort_rows(rows: List[jnp.ndarray]) -> List[jnp.ndarray]:
    """Odd-even transposition sort across a list of rows (axis 0).

    Fully unrolled for the static row count (worker counts are <= a few
    dozen): no data-dependent control flow, exactly ``m * (m - 1) / 2``
    min/max pairs on the row vectors — the TPU-safe substitute for
    ``jnp.sort(axis=0)`` inside a kernel body.

    Args:
      rows: list of equally-shaped arrays, one per row of the stack
        being sorted (``(block_d,)`` lane vectors inside a kernel).

    Returns:
      New list with the rows sorted ascending per element (the inputs
      are not mutated).
    """
    m = len(rows)
    rows = list(rows)
    for p in range(m):
        for i in range(p % 2, m - 1, 2):
            a, b = rows[i], rows[i + 1]
            rows[i] = jnp.minimum(a, b)
            rows[i + 1] = jnp.maximum(a, b)
    return rows


def bulyan_window(rows: List[jnp.ndarray], f: int) -> jnp.ndarray:
    """Bulyan's coordinate phase on an already-sorted row list.

    Per element: the mean of the ``beta = theta - 2f`` sorted values
    closest to the median.  The beta-closest set is a *contiguous
    window* of the sorted order, so it reduces to prefix sums plus an
    unrolled argmin over ``theta - beta + 1`` windows (first-window
    tiebreak) — no gather, no second sort.

    Args:
      rows: ``theta`` sorted rows (ascending per element), e.g. the
        output of :func:`oe_sort_rows`.
      f: Byzantine bound; requires ``beta = theta - 2f >= 1``.

    Returns:
      One row: per element, the best window mean.
    """
    theta = len(rows)
    beta = theta - 2 * f
    med = rows[(theta - 1) // 2]

    if beta == theta:
        acc = rows[0]
        for r in rows[1:]:
            acc = acc + r
        return acc / beta

    # prefix sums of sorted values and |sorted - med|
    pref_v = [jnp.zeros_like(med)]
    pref_d = [jnp.zeros_like(med)]
    for r in rows:
        pref_v.append(pref_v[-1] + r)
        pref_d.append(pref_d[-1] + jnp.abs(r - med))

    n_win = theta - beta + 1
    best_dev = pref_d[beta] - pref_d[0]
    best_sum = pref_v[beta] - pref_v[0]
    for w in range(1, n_win):
        dev = pref_d[w + beta] - pref_d[w]
        s = pref_v[w + beta] - pref_v[w]
        take = dev < best_dev                      # first-window tiebreak
        best_dev = jnp.where(take, dev, best_dev)
        best_sum = jnp.where(take, s, best_sum)
    return best_sum / beta


def coord_median(rows: List[jnp.ndarray]) -> jnp.ndarray:
    """Coordinate-wise median of an already-sorted row list.

    Args:
      rows: ``n`` sorted rows (ascending per element).

    Returns:
      One row: the middle row for odd ``n``, the mean of the two middle
      rows for even ``n`` (matching ``jnp.median(axis=0)``).
    """
    n = len(rows)
    if n % 2:
        return rows[n // 2]
    return 0.5 * (rows[n // 2 - 1] + rows[n // 2])


def coord_trimmed_mean(rows: List[jnp.ndarray], f: int) -> jnp.ndarray:
    """Coordinate-wise f-trimmed mean of an already-sorted row list.

    Args:
      rows: ``n`` sorted rows (ascending per element); requires
        ``n > 2f``.
      f: trim count per side.

    Returns:
      One row: the mean of rows ``f .. n - f - 1``.
    """
    n = len(rows)
    acc = rows[f]
    for r in rows[f + 1:n - f]:
        acc = acc + r
    return acc / (n - 2 * f)
