"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: straightforward, allocation-heavy
implementations with no tiling.  Kernel tests sweep shapes/dtypes and
``assert_allclose`` against these.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["bulyan_select_ref", "coord_stats_ref", "pairwise_gram_ref"]


def pairwise_gram_ref(grads: jnp.ndarray) -> jnp.ndarray:
    """(n, d) -> (n, n) squared euclidean distances, fp32 accumulation."""
    g = grads.astype(jnp.float32)
    sq = jnp.sum(g * g, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (g @ g.T)
    d2 = jnp.maximum(d2, 0.0)
    return d2 * (1.0 - jnp.eye(g.shape[0], dtype=jnp.float32))


def bulyan_select_ref(selected: jnp.ndarray, f: int) -> jnp.ndarray:
    """(theta, d) -> (d,): per-coordinate average of the beta = theta - 2f
    values closest to the coordinate-wise (lower-middle) median.  Literal
    transcription of the paper's formula."""
    theta = selected.shape[0]
    beta = theta - 2 * f
    assert beta >= 1, (theta, f)
    x = selected.astype(jnp.float32)
    s = jnp.sort(x, axis=0)
    med = s[(theta - 1) // 2]
    dist = jnp.abs(x - med[None, :])
    order = jnp.argsort(dist, axis=0)[:beta]
    closest = jnp.take_along_axis(x, order, axis=0)
    return jnp.mean(closest, axis=0)


def coord_stats_ref(grads: jnp.ndarray, f: int):
    """(n, d) -> (median, f-trimmed mean), fp32."""
    x = jnp.sort(grads.astype(jnp.float32), axis=0)
    n = x.shape[0]
    med = jnp.median(x, axis=0)
    trim = jnp.mean(x[f:n - f], axis=0)
    return med, trim
