"""Pallas TPU kernel: Bulyan's coordinate-wise phase, fused.

Per coordinate: median of the theta selected values (1-D medoid = the
lower-middle order statistic), then the average of the beta = theta - 2f
values closest to it.  This is pure VPU work over d coordinates — the
memory-bound hot loop of Bulyan (Proposition 1's ``O(d n)`` term), so the
kernel's job is to stream d through VMEM in blocks and do everything for a
block in registers:

  * the sort is an odd-even transposition network, fully unrolled for the
    static worker count theta (<= ~32): no data-dependent control flow,
    exactly theta*(theta-1)/2 min/max pairs on (block_d,)-wide lanes;
  * the "beta closest to the median" set is a *contiguous window* of the
    sorted order, so it reduces to prefix sums + an unrolled argmin over
    theta - beta + 1 windows — no gather, no second sort;
  * one fused pass: HBM traffic = read theta*block_d, write block_d.

Grid = (d / block_d,); blocks are fully independent (embarrassingly parallel
over coordinates — the same fact that lets the distributed runtime shard
this phase over the `model` mesh axis).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (bulyan_window, oe_sort_rows,
                                  resolve_interpret)

__all__ = ["bulyan_select"]

# historic private alias: the sort network now lives in
# repro.kernels.common (coord_stats and fused_agg share it)
_oe_sort_rows = oe_sort_rows


def _make_kernel(theta: int, f: int):
    def kernel(sel_ref, out_ref):
        x = sel_ref[...].astype(jnp.float32)          # (theta, block_d)
        rows = oe_sort_rows([x[i] for i in range(theta)])
        out_ref[...] = bulyan_window(rows, f)[None, :]

    return kernel


@functools.partial(jax.jit, static_argnames=("f", "block_d", "interpret"))
def bulyan_select(selected: jnp.ndarray, f: int, *, block_d: int = 2048,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Bulyan coordinate phase, fused.

    Args:
      selected: ``(theta, d)`` stack of the theta selected gradients.
      f: Byzantine bound; requires ``beta = theta - 2f >= 1``.
      block_d: VMEM tile width along d.
      interpret: ``None`` resolves per backend (compiled on TPU,
        interpreter elsewhere); see ``pairwise_gram.resolve_interpret``.

    Returns:
      ``(d,)`` float32: per coordinate, the mean of the beta sorted
      values closest to the median.

    VMEM per step ~ (theta + 1) * block_d * 4 bytes (slab + output row) plus
    the unrolled temporaries; with theta = 16, block_d = 2048 that is well
    under VMEM even with double buffering.
    """
    theta, d = selected.shape
    beta = theta - 2 * f
    if beta < 1:
        raise ValueError(f"need theta > 2f (theta={theta}, f={f})")
    block_d = min(block_d, max(d, 128))
    pad = (-d) % block_d
    if pad:
        selected = jnp.pad(selected, ((0, 0), (0, pad)))
    dp = selected.shape[1]
    out = pl.pallas_call(
        _make_kernel(theta, f),
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((theta, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(selected)
    return out[0, :d]
