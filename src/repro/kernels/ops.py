"""Public jit'd entry points for the Pallas kernels.

``use_pallas`` toggles between the kernel (interpret-mode on CPU, compiled
on TPU) and the pure-jnp oracle.  The GAR core calls these through
``repro.kernels.ops`` so a single flag flips the whole framework.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bulyan_select import bulyan_select as _bulyan_select
from repro.kernels.pairwise_gram import pairwise_gram as _pairwise_gram

__all__ = ["bulyan_coordinate", "pairwise_distances"]

# Pallas interpret mode is pure-Python per grid step — correct everywhere,
# fast only on TPU.  Default to the oracle on CPU, the kernel on TPU.
_ON_TPU = jax.default_backend() == "tpu"


def pairwise_distances(grads: jnp.ndarray, *,
                       use_pallas: Optional[bool] = None,
                       block_d: int = 4096) -> jnp.ndarray:
    """Squared pairwise distances; kernel or oracle.

    Args:
      grads: ``(n, d)`` worker-stacked flat gradients.
      use_pallas: ``None`` picks the kernel on TPU and the jnp oracle
        elsewhere; ``True`` forces the kernel (interpreter off-TPU).
      block_d: kernel VMEM tile width.

    Returns:
      ``(n, n)`` float32 squared distances, zero diagonal.
    """
    if use_pallas is None:
        use_pallas = _ON_TPU
    if use_pallas:
        return _pairwise_gram(grads, block_d=block_d)
    return ref.pairwise_gram_ref(grads)


def bulyan_coordinate(selected: jnp.ndarray, f: int, *,
                      use_pallas: Optional[bool] = None,
                      block_d: int = 2048) -> jnp.ndarray:
    """Bulyan coordinate phase; kernel or oracle.

    Args:
      selected: ``(theta, d)`` selected-gradient stack.
      f: Byzantine bound (``beta = theta - 2f``).
      use_pallas: ``None`` picks the kernel on TPU, the pure-jnp
        ``repro.core.bulyan.coordinate_phase`` elsewhere.
      block_d: kernel VMEM tile width.

    Returns:
      ``(d,)`` float32 coordinate-phase aggregate.
    """
    if use_pallas is None:
        use_pallas = _ON_TPU
    if use_pallas:
        return _bulyan_select(selected, f, block_d=block_d)
    from repro.core.bulyan import coordinate_phase
    return coordinate_phase(selected, f)
