"""Pallas TPU kernels for the aggregation hot spots.

  pairwise_gram  — (n, n) squared-distance matrix via d-tiled MXU Gram
                   accumulation (feeds Krum/GeoMed/Brute/Bulyan selection).
  bulyan_select  — fused coordinate-wise median + beta-closest-average
                   (Bulyan phase 2) with an unrolled odd-even sorting
                   network and windowed prefix sums (VPU, gather-free).
  coord_stats    — coordinate-wise median + f-trimmed mean from one
                   shared sort (the cwmed / trimmed_mean GARs).
  fused_agg      — the megakernel: distance accumulation, in-kernel
                   selection and the coordinate phase in one sweep
                   (``distance_backend="fused"``), plus the per-leaf
                   select+combine pair kernel for gradient trees.

``common`` holds the shared primitives (sort network, window/median/trim
combine bodies, interpret resolution) the kernels import *down* into,
``ops`` the jit'd wrappers, ``ref`` the pure-jnp oracles used by the
shape/dtype-sweep tests, and ``probes`` the fp32-accumulation contract
probes the adversarial self-audit (``repro.audit``) sweeps.
"""
from repro.kernels.bulyan_select import bulyan_select
from repro.kernels.common import resolve_interpret
from repro.kernels.coord_stats import coord_stats
from repro.kernels.fused_agg import (fused_aggregate, fused_coordinate,
                                     select_weights)
from repro.kernels.pairwise_gram import (pairwise_gram,
                                         pairwise_gram_partial,
                                         pairwise_gram_tree)
from repro.kernels import ops, probes, ref

__all__ = ["bulyan_select", "coord_stats", "fused_aggregate",
           "fused_coordinate", "ops", "pairwise_gram",
           "pairwise_gram_partial", "pairwise_gram_tree", "probes", "ref",
           "resolve_interpret", "select_weights"]
