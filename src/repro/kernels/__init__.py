"""Pallas TPU kernels for the aggregation hot spots.

  pairwise_gram  — (n, n) squared-distance matrix via d-tiled MXU Gram
                   accumulation (feeds Krum/GeoMed/Brute/Bulyan selection).
  bulyan_select  — fused coordinate-wise median + beta-closest-average
                   (Bulyan phase 2) with an unrolled odd-even sorting
                   network and windowed prefix sums (VPU, gather-free).

``ops`` holds the jit'd wrappers, ``ref`` the pure-jnp oracles used by the
shape/dtype-sweep tests, and ``probes`` the fp32-accumulation contract
probes the adversarial self-audit (``repro.audit``) sweeps.
"""
from repro.kernels.bulyan_select import bulyan_select
from repro.kernels.coord_stats import coord_stats
from repro.kernels.pairwise_gram import (pairwise_gram,
                                         pairwise_gram_partial,
                                         pairwise_gram_tree)
from repro.kernels import ops, probes, ref

__all__ = ["bulyan_select", "coord_stats", "ops", "pairwise_gram",
           "pairwise_gram_partial", "pairwise_gram_tree", "probes", "ref"]
