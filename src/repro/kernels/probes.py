"""fp32-accumulation contract probes for the kernel layer.

Every kernel in this package promises the same numeric contract the
sharded engine documents (``repro.dist.robust``): inputs may stream from
HBM in their native dtype (bf16 at production scale), but *accumulation
happens in fp32 on-chip* and the result is an fp32 artifact.  A kernel
edit that silently accumulates in bf16 would pass shape checks and most
value tests at small d — and quietly widen the very ε-leeway the paper
bounds, because distance-based selection then runs on distances whose
error grows with d.

These probes make the contract empirically checkable: each one feeds the
kernel a bf16 (or otherwise low-precision) worker stack and compares it
against the pure-jnp fp32 oracle *on the identical quantized values* —
so the only admissible difference is summation order, and the relative
error bound can stay tight no matter how large d grows.  The adversarial
self-audit (``repro.audit.sweep``) runs them across a (n, d, block_d)
grid; ``tests/test_kernels.py`` pins the small cases.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bulyan import coordinate_phase
from repro.kernels.bulyan_select import bulyan_select
from repro.kernels.pairwise_gram import pairwise_gram

__all__ = ["coord_fp32_contract_error", "fused_fp32_contract_error",
           "gram_fp32_contract_error"]


def _rel_err(got: jnp.ndarray, want: jnp.ndarray) -> float:
    scale = float(jnp.max(jnp.abs(want))) or 1.0
    return float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) / scale


def gram_fp32_contract_error(n: int = 8, d: int = 4096,
                             dtype=jnp.bfloat16, *, block_d: int = 1024,
                             seed: int = 0,
                             interpret: Optional[bool] = None) -> float:
    """Max relative error of the Pallas distance pass vs the fp32 oracle.

    Args:
      n: worker count of the probe stack.
      d: coordinate count — spanning several ``block_d`` tiles so the
        cross-tile accumulation path is exercised (where a bf16
        accumulator would lose bits).
      dtype: input dtype streamed to the kernel (default bf16, the
        production HBM format).
      block_d: kernel VMEM tile width.
      seed: PRNG seed of the probe stack.
      interpret: Pallas interpret override (``None`` = auto; the
        interpreter runs the identical accumulation code path on CPU).

    Returns:
      ``max |kernel - oracle| / max |oracle|`` where the oracle casts
      the *same* quantized inputs to fp32 before the Gram contraction —
      ~1e-6 when the kernel honours the fp32-accumulation contract,
      O(1e-2) and growing with d if it ever accumulates in bf16.
    """
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, d),
                          jnp.float32).astype(dtype)
    got = pairwise_gram(g, block_d=block_d, interpret=interpret)
    from repro.kernels.ref import pairwise_gram_ref
    want = pairwise_gram_ref(g.astype(jnp.float32))
    return _rel_err(got, want)


def coord_fp32_contract_error(theta: int = 9, f: int = 2, d: int = 4096,
                              dtype=jnp.bfloat16, *, block_d: int = 1024,
                              seed: int = 0,
                              interpret: Optional[bool] = None) -> float:
    """Max relative error of the Bulyan coordinate kernel vs fp32 oracle.

    Args:
      theta: selected-stack height (phase-1 output size).
      f: Byzantine bound (``beta = theta - 2f`` window).
      d: coordinate count across several tiles.
      dtype: input dtype streamed to the kernel.
      block_d: kernel VMEM tile width.
      seed: PRNG seed of the probe stack.
      interpret: Pallas interpret override (``None`` = auto).

    Returns:
      Max relative error against ``repro.core.bulyan.coordinate_phase``
      run on the fp32 cast of the identical quantized stack — tight when
      the kernel's window sums accumulate fp32.
    """
    s = jax.random.normal(jax.random.PRNGKey(seed), (theta, d),
                          jnp.float32).astype(dtype)
    got = bulyan_select(s, f, block_d=block_d, interpret=interpret)
    want = coordinate_phase(s.astype(jnp.float32), f)
    return _rel_err(got, want)


def fused_fp32_contract_error(n: int = 11, f: int = 2, d: int = 4096,
                              dtype=jnp.bfloat16, *,
                              mode: str = "bulyan-krum",
                              block_d: int = 1024, seed: int = 0,
                              interpret: Optional[bool] = None) -> float:
    """Max relative error of the fused megakernel vs the flat fp32 rule.

    The megakernel (``repro.kernels.fused_agg``) chains all three
    accumulation sites — the d-tiled Gram sweep, the selection-weight
    contraction and the coordinate phase — inside one kernel, so a bf16
    accumulator anywhere in the chain shows up here even if the
    standalone kernel probes stay green.

    Args:
      n: worker count of the probe stack (``>= 4f + 3`` for the bulyan
        modes).
      f: Byzantine bound.
      d: coordinate count spanning several ``block_d`` tiles.
      dtype: input dtype streamed to the kernel (default bf16).
      mode: fused mode to probe (any of
        ``repro.kernels.fused_agg.FUSED_MODES``).
      block_d: kernel VMEM tile width.
      seed: PRNG seed of the probe stack.
      interpret: Pallas interpret override (``None`` = auto).

    Returns:
      Max relative error of ``fused_aggregate`` on the quantized stack
      against the registry's dense rule run on the fp32 cast of the
      *identical* quantized values — tight (<= ~1e-4) under the fp32
      contract, growing with d if any stage accumulates in bf16.
    """
    from repro.agg.registry import resolve_rule
    from repro.kernels.fused_agg import fused_aggregate
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, d),
                          jnp.float32).astype(dtype)
    got, _, _ = fused_aggregate(g, f, mode=mode, block_d=block_d,
                                interpret=interpret)
    want = resolve_rule(mode).dense_fn(g.astype(jnp.float32), f).gradient
    return _rel_err(got, want)
