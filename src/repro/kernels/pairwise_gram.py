"""Pallas TPU kernel: pairwise squared-distance (Gram) matrix over d-tiles.

Krum / GeoMed / Brute / Bulyan-selection all start from the (n, n) matrix of
squared distances between worker gradients, n <= ~32, d up to billions.  The
contraction is a Gram matmul — MXU work — whose input must stream through
VMEM in d-tiles.  Grid = (d / block_d,); each step loads an (n, block_d)
slab, computes the partial ``|x|^2 + |y|^2 - 2 x.yT`` and accumulates into
the single (n, n) output block that stays resident in VMEM across steps.

VMEM budget per step: n * block_d * 4 bytes (slab) + n*n*4 (accumulator).
With n = 32 and block_d = 4096 that is ~512 KiB — far under the ~16 MiB
v5e VMEM, leaving room for double buffering of the HBM stream.

Three entry points, one kernel:

  pairwise_gram          (n, d) -> (n, n) distances (the classic API)
  pairwise_gram_partial  raw un-clamped partial over one slab — the
                         accumulable building block: partials over disjoint
                         coordinate slices *sum* to the partial over their
                         concatenation, which is what both the pytree and
                         the shard_map paths exploit
  pairwise_gram_tree     partial per pytree leaf (ragged trailing dims are
                         flattened per leaf), summed, then finalized

``interpret=None`` (the default everywhere) resolves from
``jax.default_backend()``: the compiled kernel on TPU, the Pallas
interpreter on CPU/GPU — so the same call sites run in CPU CI and on a pod.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# historic import path: callers imported resolve_interpret from here
# before it was hoisted to repro.kernels.common
from repro.kernels.common import resolve_interpret

__all__ = ["finalize_dists", "pairwise_gram", "pairwise_gram_partial",
           "pairwise_gram_tree", "resolve_interpret"]


def _gram_kernel(g_ref, out_ref):
    i = pl.program_id(0)
    blk = g_ref[...].astype(jnp.float32)          # (n, block_d)
    sq = jnp.sum(blk * blk, axis=1)               # (n,)
    gram = jax.lax.dot_general(
        blk, blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (n, n) on the MXU
    part = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


def finalize_dists(raw: jnp.ndarray) -> jnp.ndarray:
    """Turn summed raw partials into a valid distance matrix.

    Args:
      raw: ``(n, n)`` sum of ``pairwise_gram_partial`` outputs (any
        backend — also used by the tensordot path in
        ``repro.dist.robust``).

    Returns:
      ``(n, n)`` with fp-cancellation negatives clamped to zero and the
      diagonal zeroed (exact by definition).
    """
    n = raw.shape[0]
    out = jnp.maximum(raw, 0.0)
    return out * (1.0 - jnp.eye(n, dtype=out.dtype))


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_gram_partial(slab: jnp.ndarray, *, block_d: int = 4096,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Raw distance partial of one coordinate slab — the accumulable form.

    Args:
      slab: ``(n, *dims)`` worker-stacked coordinate slice; trailing dims
        are flattened (distances are permutation-invariant over
        coordinates, so any flattening order is exact).
      block_d: VMEM tile width along the flattened coordinate axis.
      interpret: see ``resolve_interpret``.

    Returns:
      ``(n, n)`` float32 ``sq_i + sq_j - 2 <x_i, x_j>`` over this slab's
      coordinates only — NOT clamped and with a nonzero diagonal, so that
      partials over disjoint slabs (pytree leaves, model shards) sum to
      the partial over their union.  Finalize with the module-level
      clamp once all partials are summed (``pairwise_gram`` does both).
    """
    n = slab.shape[0]
    slab = slab.reshape(n, -1)
    d = slab.shape[1]
    block_d = min(block_d, max(d, 128))
    pad = (-d) % block_d
    if pad:
        # zero padding adds |0-0|^2 = 0 to every distance: exact
        slab = jnp.pad(slab, ((0, 0), (0, pad)))
    dp = slab.shape[1]
    return pl.pallas_call(
        _gram_kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((n, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(slab)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_gram(grads: jnp.ndarray, *, block_d: int = 4096,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Pairwise squared euclidean distances of worker gradient rows.

    Args:
      grads: ``(n, d)`` worker-stacked flat gradients, any float dtype
        (accumulation is fp32 inside the kernel).
      block_d: VMEM tile width along d.
      interpret: see ``resolve_interpret`` (default: auto per backend).

    Returns:
      ``(n, n)`` float32 squared distances, non-negative, zero diagonal.
    """
    return finalize_dists(pairwise_gram_partial(
        grads, block_d=block_d, interpret=interpret))


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_gram_tree(tree: Any, *, block_d: int = 4096,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Distances over the concatenation of all pytree leaves.

    Args:
      tree: pytree whose leaves are ``(n, *dims)`` with a shared leading
        worker axis; trailing dims may be ragged across leaves — each
        leaf is flattened and tiled independently.
      block_d: VMEM tile width per leaf.
      interpret: see ``resolve_interpret``.

    Returns:
      ``(n, n)`` float32 squared distances over the concatenated
      coordinate space — no flat ``(n, d)`` matrix is ever built.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("empty gradient tree")
    n = leaves[0].shape[0]
    raw = jnp.zeros((n, n), jnp.float32)
    for leaf in leaves:
        raw = raw + pairwise_gram_partial(
            leaf, block_d=block_d, interpret=interpret)
    return finalize_dists(raw)
