"""Pallas TPU kernel: pairwise squared-distance (Gram) matrix over d-tiles.

Krum / GeoMed / Brute / Bulyan-selection all start from the (n, n) matrix of
squared distances between worker gradients, n <= ~32, d up to billions.  The
contraction is a Gram matmul — MXU work — whose input must stream through
VMEM in d-tiles.  Grid = (d / block_d,); each step loads an (n, block_d)
slab, computes the partial ``|x|^2 + |y|^2 - 2 x.yT`` and accumulates into
the single (n, n) output block that stays resident in VMEM across steps.

VMEM budget per step: n * block_d * 4 bytes (slab) + n*n*4 (accumulator).
With n = 32 and block_d = 4096 that is ~512 KiB — far under the ~16 MiB
v5e VMEM, leaving room for double buffering of the HBM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(g_ref, out_ref):
    i = pl.program_id(0)
    blk = g_ref[...].astype(jnp.float32)          # (n, block_d)
    sq = jnp.sum(blk * blk, axis=1)               # (n,)
    gram = jax.lax.dot_general(
        blk, blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (n, n) on the MXU
    part = sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_gram(grads: jnp.ndarray, *, block_d: int = 4096,
                  interpret: bool = True) -> jnp.ndarray:
    """(n, d) -> (n, n) squared euclidean distances.

    ``interpret=True`` runs the kernel body in the Pallas interpreter (this
    container is CPU-only); on real TPU pass ``interpret=False``.
    """
    n, d = grads.shape
    block_d = min(block_d, max(d, 128))
    pad = (-d) % block_d
    if pad:
        # zero padding adds |0-0|^2 = 0 to every distance: exact
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    dp = grads.shape[1]
    out = pl.pallas_call(
        _gram_kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((n, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(grads)
    out = jnp.maximum(out, 0.0)
    return out * (1.0 - jnp.eye(n, dtype=out.dtype))
