"""Pallas TPU kernel: fused coordinate-wise robust statistics.

Computes, in a single pass over d-tiled VMEM blocks, both
  * the coordinate-wise median (the cwmed GAR), and
  * the coordinate-wise f-trimmed mean (the trimmed_mean GAR)
from one odd-even sorting network over the n worker rows — the two
baseline coordinate-wise rules share their sort, so a fused kernel halves
the HBM traffic versus running them separately (both are pure VPU work,
memory-bound by construction).

Same structure as bulyan_select: grid over d blocks, rows unrolled
(n <= ~64), no data-dependent control flow.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (coord_median, coord_trimmed_mean,
                                  oe_sort_rows, resolve_interpret)

__all__ = ["coord_stats"]


def _make_kernel(n: int, f: int):
    def kernel(g_ref, med_ref, trim_ref):
        x = g_ref[...].astype(jnp.float32)            # (n, block_d)
        rows = oe_sort_rows([x[i] for i in range(n)])
        med_ref[...] = coord_median(rows)[None, :]
        trim_ref[...] = coord_trimmed_mean(rows, f)[None, :]

    return kernel


@functools.partial(jax.jit, static_argnames=("f", "block_d", "interpret"))
def coord_stats(grads: jnp.ndarray, f: int, *, block_d: int = 2048,
                interpret: Optional[bool] = None):
    """Fused coordinate-wise median + f-trimmed mean.

    Args:
      grads: ``(n, d)`` worker-stacked flat gradients; requires n > 2f.
      f: trim count per side.
      block_d: VMEM tile width along d.
      interpret: ``None`` resolves per backend (compiled on TPU,
        interpreter elsewhere).

    Returns:
      ``(median, trimmed_mean)``, each ``(d,)`` float32.
    """
    n, d = grads.shape
    if n <= 2 * f:
        raise ValueError(f"need n > 2f (n={n}, f={f})")
    block_d = min(block_d, max(d, 128))
    pad = (-d) % block_d
    if pad:
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    dp = grads.shape[1]
    med, trim = pl.pallas_call(
        _make_kernel(n, f),
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((n, block_d), lambda i: (0, i))],
        out_specs=(pl.BlockSpec((1, block_d), lambda i: (0, i)),
                   pl.BlockSpec((1, block_d), lambda i: (0, i))),
        out_shape=(jax.ShapeDtypeStruct((1, dp), jnp.float32),
                   jax.ShapeDtypeStruct((1, dp), jnp.float32)),
        interpret=resolve_interpret(interpret),
    )(grads)
    return med[0, :d], trim[0, :d]
