"""Pallas TPU megakernel: Gram -> select -> coordinate phase, one sweep.

The robust-aggregation hot path used to be three kernels chained through
HBM — ``pairwise_gram`` (distances), a host-side selection, then
``bulyan_select`` / ``coord_stats`` on a gathered ``(theta, d)`` copy.
Every stage re-streams O(n * d) bytes.  This module fuses them into a
single ``pallas_call`` over a two-phase grid:

  phase 0 (distance sweep): each step loads one ``(n, block_d)`` slab,
      computes the partial ``|x|^2 + |y|^2 - 2 x.yT`` on the MXU and
      accumulates it into an ``(n, n)`` raw-Gram block that stays
      resident in VMEM across steps (same structure as
      ``pairwise_gram``);

  phase 1 (select + combine): at the first step the resident raw Gram
      is finalized and the selection runs *in-kernel* — Krum scores via
      the odd-even network over the symmetric distance matrix, Bulyan's
      recursive extraction as a statically unrolled masked-argmin loop —
      leaving a ``(theta, n)`` one-hot weight block in VMEM.  Every
      phase-1 step then re-loads its slab, gathers the selected rows as
      an exact one-hot f32 matmul and applies the coordinate phase
      (``bulyan_window`` / mean) before writing the ``(1, block_d)``
      output tile.

HBM traffic per aggregation: read ``2 * n * d`` (two input sweeps),
write ``d`` — versus ``>= 3 n d + 2 theta d`` for the chained kernels.
No ``(theta, d)`` gather and no intermediate distance round-trip ever
touch HBM; only the tiny ``(n, n)`` / ``(theta, n)`` diagnostics do.
Inputs stream in their native dtype (bf16 at production scale) and all
accumulation is fp32 on-chip — the same contract the other kernels
honour, probed by ``repro.kernels.probes.fused_fp32_contract_error``.

Selection is TPU-safe by construction: no ``argsort`` / ``argmin`` /
1-D iota in the kernel body.  Sorted neighbour distances come from the
odd-even network applied across the *rows* of the symmetric distance
matrix (the k-th smallest of column j equals the k-th smallest of row
j); first-index argmins are built from 2-D ``broadcasted_iota`` + min
reductions; availability masks are ``(1, n)`` float vectors updated in
statically unrolled Python loops — mirroring ``repro.core.bulyan``'s
remaining-index recursion pick for pick.

Multi-leaf gradient trees use the tight kernel *pair* instead: the
per-leaf ``pairwise_gram_partial`` accumulation (leaves sum raw
partials), the same :func:`select_weights` helper under plain jit, and
:func:`fused_coordinate` per leaf — select + coordinate phase in one
kernel, still without materializing a ``(theta, d)`` gather.  Because
the in-kernel and out-of-kernel paths share one selection function, the
two lowerings are bitwise-comparable (``tests/test_fused_agg.py``).

Exposed to the stack as ``distance_backend="fused"`` (see
``repro.dist.robust``) and as the ``fused-<base>`` registry composites
(``repro.agg.fused``).  Design notes and the tiling diagram live in
docs/kernels.md.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (bulyan_window, coord_median,
                                  coord_trimmed_mean, oe_sort_rows,
                                  resolve_interpret)

__all__ = ["COORD_MODES", "DIST_MODES", "FUSED_MODES", "fused_aggregate",
           "fused_coordinate", "select_weights"]

#: modes whose selection consumes the (n, n) distance matrix
DIST_MODES: Tuple[str, ...] = ("bulyan-geomed", "bulyan-krum", "geomed",
                               "krum", "multikrum")

#: coordinate-only modes (no distance phase at all)
COORD_MODES: Tuple[str, ...] = ("cwmed", "trimmed_mean")

#: every mode the fused kernels lower (== repro.agg.fused.FUSED_BASES)
FUSED_MODES: Tuple[str, ...] = tuple(sorted(DIST_MODES + COORD_MODES))

#: unrolled sort/selection networks are O(n^2)-O(n^3) ops at trace time
_MAX_N = 64


def _weight_rows(n: int, f: int, mode: str) -> int:
    """Row count of the selection-weight matrix for one mode."""
    return n - 2 * f if mode.startswith("bulyan") else 1


def _check_mode_shape(n: int, f: int, mode: str) -> None:
    """Trace-time structural checks shared by both kernel entry points."""
    if mode not in FUSED_MODES:
        raise KeyError(f"unknown fused mode {mode!r}; have "
                       f"{sorted(FUSED_MODES)}")
    if n > _MAX_N:
        raise ValueError(
            f"fused kernels unroll sort/select networks: n <= {_MAX_N} "
            f"(got n={n})")
    if mode.startswith("bulyan") and n < 4 * f + 3:
        raise ValueError(f"bulyan requires n >= 4f+3, got n={n}, f={f}")
    if mode in ("krum", "multikrum") and n - f - 2 < 1:
        raise ValueError(
            f"krum needs n >= f + 3 per use (n={n}, f={f})")
    if mode == "trimmed_mean" and n <= 2 * f:
        raise ValueError(f"need n > 2f (n={n}, f={f})")


# ---------------------------------------------------------------------------
# selection on the (n, n) distance matrix — shared in-/out-of-kernel
# ---------------------------------------------------------------------------

def _iota_row(n: int) -> jnp.ndarray:
    """(1, n) int32 lane indices (2-D iota: TPU kernels reject 1-D)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)


def _first_argmin_onehot(scores: jnp.ndarray, n: int) -> jnp.ndarray:
    """(1, n) scores -> (1, n) f32 one-hot at the first (smallest-index)
    minimum — the argmin convention of every selection rule in the repo."""
    iota = _iota_row(n)
    m = jnp.min(scores)
    idx = jnp.min(jnp.where(scores == m, iota, n))
    return (iota == idx).astype(jnp.float32)


def _masked_dists(d2: jnp.ndarray, avail: jnp.ndarray,
                  n: int) -> jnp.ndarray:
    """Diagonal and rows/cols of unavailable workers -> +inf (the
    ``repro.core.gars._masked`` convention, iota/outer-product form)."""
    vmat = jax.lax.dot_general(
        avail, avail, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (n, n) outer product
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return jnp.where((r == c) | (vmat < 0.5), jnp.inf, d2)


def _krum_scores(dm: jnp.ndarray, avail: jnp.ndarray, f: int, n_rem: int,
                 n: int) -> jnp.ndarray:
    """Krum scores on a masked matrix: per worker, the sum of the
    ``k = max(1, n_rem - f - 2)`` smallest remaining distances.  The
    matrix is symmetric, so sorting across its *rows* with the odd-even
    network yields each column's (== each worker's) sorted neighbour
    distances without a per-row sort."""
    k = max(1, n_rem - f - 2)
    cols = oe_sort_rows([dm[i:i + 1, :] for i in range(n)])
    s = cols[0]
    for r in cols[1:k]:
        s = s + r
    return jnp.where(avail > 0.5, s, jnp.inf)


def _geomed_scores(dm: jnp.ndarray, avail: jnp.ndarray,
                   n: int) -> jnp.ndarray:
    """Medoid scores: per worker, the sum of non-squared distances to the
    remaining workers (masked +inf entries contribute zero, as in
    ``repro.core.gars.geomed_scores``); axis-0 sum == axis-1 sum by
    symmetry and keeps the result a (1, n) lane vector."""
    dist = jnp.sqrt(jnp.where(jnp.isinf(dm), 0.0, dm))
    s = jnp.sum(dist, axis=0, keepdims=True)
    return jnp.where(avail > 0.5, s, jnp.inf)


def select_weights(dist2: jnp.ndarray, n: int, f: int, mode: str):
    """Selection weights of one fused mode from finalized distances.

    This single function is the selection semantics of the fused path:
    the megakernel calls it on the VMEM-resident distance block, and the
    multi-leaf tree path calls it under plain jit on the all-reduced
    matrix — so the two lowerings are bitwise-identical by construction.
    Every op is TPU-kernel-safe (2-D iota, min/max networks, one-hot
    matmuls; no argsort/argmin/gather).

    Args:
      dist2: ``(n, n)`` finalized squared distances (non-negative, zero
        diagonal), any float dtype.
      n: worker count (static).
      f: Byzantine bound (static).
      mode: one of :data:`DIST_MODES` — ``"krum"`` / ``"geomed"``
        (one-hot winner), ``"multikrum"`` (uniform over the m best
        scores), ``"bulyan-krum"`` / ``"bulyan-geomed"`` (the theta
        = n - 2f recursive picks, mirroring
        ``repro.core.bulyan.select_indices_from_dists``).

    Returns:
      ``(weights, selected, scores)``: ``weights`` is the
      ``(theta_w, n)`` f32 combination matrix (``theta_w`` rows of
      one-hots for bulyan, one row of convex weights otherwise),
      ``selected`` the ``(1, n)`` diagnostic marks (convex weights, or
      1.0 per bulyan pick), ``scores`` the ``(1, n)`` rule scores
      (zeros for bulyan, matching the dense composites).
    """
    d2 = dist2.astype(jnp.float32)
    avail = jnp.ones((1, n), jnp.float32)
    if mode in ("krum", "geomed"):
        dm = _masked_dists(d2, avail, n)
        scores = (_krum_scores(dm, avail, f, n, n) if mode == "krum"
                  else _geomed_scores(dm, avail, n))
        hot = _first_argmin_onehot(scores, n)
        return hot, hot, scores
    if mode == "multikrum":
        scores = _krum_scores(_masked_dists(d2, avail, n), avail, f, n, n)
        m = max(1, n - f - 2)
        acc = jnp.zeros((1, n), jnp.float32)
        cur = scores
        for _ in range(m):
            hot = _first_argmin_onehot(cur, n)
            acc = acc + hot
            cur = jnp.where(hot > 0.5, jnp.inf, cur)
        w = acc / m
        return w, w, scores
    if mode not in ("bulyan-krum", "bulyan-geomed"):
        raise KeyError(f"select_weights needs a distance mode, got "
                       f"{mode!r}")
    base = mode.split("-", 1)[1]
    theta = n - 2 * f
    picks = []
    sel = jnp.zeros((1, n), jnp.float32)
    for t in range(theta):
        n_rem = n - t
        dm = _masked_dists(d2, avail, n)
        scores = (_krum_scores(dm, avail, f, n_rem, n) if base == "krum"
                  else _geomed_scores(dm, avail, n))
        hot = _first_argmin_onehot(scores, n)
        picks.append(hot)
        sel = sel + hot
        avail = avail - hot
    w = jnp.concatenate(picks, axis=0)                # (theta, n)
    return w, sel, jnp.zeros((1, n), jnp.float32)


# ---------------------------------------------------------------------------
# per-tile combine — shared by the megakernel and the pair kernel
# ---------------------------------------------------------------------------

def _finalized(raw: jnp.ndarray, n: int) -> jnp.ndarray:
    """In-kernel ``finalize_dists``: clamp fp-cancellation negatives and
    zero the diagonal (iota-built identity; same value order as the
    ``jnp.eye`` form used outside kernels)."""
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    eye = (r == c).astype(jnp.float32)
    return jnp.maximum(raw, 0.0) * (1.0 - eye)


def _combine_tile(x: jnp.ndarray, w: Optional[jnp.ndarray], n: int, f: int,
                  mode: str) -> jnp.ndarray:
    """One output tile from one (n, block_d) f32 slab.

    Coordinate modes sort the worker rows directly; distance modes first
    contract with the selection weights — an exact row gather when the
    weights are one-hot f32 — then run the mode's reduction."""
    if mode in COORD_MODES:
        rows = oe_sort_rows([x[i] for i in range(n)])
        out = (coord_median(rows) if mode == "cwmed"
               else coord_trimmed_mean(rows, f))
        return out[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (theta_w, block_d)
    if mode.startswith("bulyan"):
        rows = oe_sort_rows([y[t] for t in range(y.shape[0])])
        return bulyan_window(rows, f)[None, :]
    return y                                          # (1, block_d) mean


# ---------------------------------------------------------------------------
# the megakernel (flat / single-leaf path)
# ---------------------------------------------------------------------------

def _make_megakernel(n: int, f: int, mode: str):
    def kernel(g_ref, agg_ref, sel_ref, score_ref, raw_ref, w_ref):
        p = pl.program_id(0)
        i = pl.program_id(1)
        x = g_ref[...].astype(jnp.float32)            # (n, block_d)

        @pl.when(p == 0)
        def _gram():
            sq = jnp.sum(x * x, axis=1)
            gram = jax.lax.dot_general(
                x, x, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)   # (n, n) on the MXU
            part = sq[:, None] + sq[None, :] - 2.0 * gram

            @pl.when(i == 0)
            def _init():
                raw_ref[...] = part

            @pl.when(i > 0)
            def _acc():
                raw_ref[...] += part

        @pl.when((p == 1) & (i == 0))
        def _select():
            d2 = _finalized(raw_ref[...], n)
            w, sel, scores = select_weights(d2, n, f, mode)
            w_ref[...] = w
            sel_ref[...] = sel
            score_ref[...] = scores

        @pl.when(p == 1)
        def _combine():
            agg_ref[...] = _combine_tile(x, w_ref[...], n, f, mode)

    return kernel


@functools.partial(jax.jit, static_argnames=("f", "mode", "block_d",
                                             "interpret"))
def fused_aggregate(grads: jnp.ndarray, f: int, *,
                    mode: str = "bulyan-krum", block_d: int = 2048,
                    interpret: Optional[bool] = None):
    """Robust-aggregate a flat worker stack in one fused kernel sweep.

    Args:
      grads: ``(n, d)`` worker-stacked flat gradients, any float dtype
        (bf16 streams thin from HBM; accumulation is fp32 in-kernel).
      f: Byzantine bound (static; quorum structure checked at trace
        time, mirroring the dense rules' own checks).
      mode: one of :data:`FUSED_MODES` — the base-rule name the kernel
        lowers (``"krum"``, ``"multikrum"``, ``"geomed"``, ``"cwmed"``,
        ``"trimmed_mean"``, ``"bulyan-krum"``, ``"bulyan-geomed"``).
      block_d: VMEM tile width along d.
      interpret: ``None`` resolves per backend (compiled on TPU, the
        Pallas interpreter elsewhere); see
        ``repro.kernels.common.resolve_interpret``.

    Returns:
      ``(gradient, selected, scores)``: the ``(d,)`` f32 aggregate, the
      ``(n,)`` f32 selection weights and the ``(n,)`` f32 rule scores —
      the same triple the dense registry rules report.
    """
    n, d = grads.shape
    _check_mode_shape(n, f, mode)
    if mode in COORD_MODES:
        agg = fused_coordinate(grads, None, f, mode=mode, block_d=block_d,
                               interpret=interpret)
        return (agg, jnp.full((n,), 1.0 / n, jnp.float32),
                jnp.zeros((n,), jnp.float32))
    block_d = min(block_d, max(d, 128))
    pad = (-d) % block_d
    if pad:
        # zero padding adds |0-0|^2 = 0 to every distance, and padded
        # output columns are sliced off below: exact
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    dp = grads.shape[1]
    theta_w = _weight_rows(n, f, mode)
    agg, sel, scores, _raw, _w = pl.pallas_call(
        _make_megakernel(n, f, mode),
        grid=(2, dp // block_d),
        in_specs=[pl.BlockSpec((n, block_d), lambda p, i: (0, i))],
        out_specs=(
            # parks on tile 0 during the distance sweep (p = 0), then
            # walks the tiles — so no phase-0 step ever flushes garbage
            pl.BlockSpec((1, block_d), lambda p, i: (0, i * p)),
            pl.BlockSpec((1, n), lambda p, i: (0, 0)),
            pl.BlockSpec((1, n), lambda p, i: (0, 0)),
            pl.BlockSpec((n, n), lambda p, i: (0, 0)),
            pl.BlockSpec((theta_w, n), lambda p, i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, dp), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            # VMEM-resident accumulators (raw Gram, selection weights):
            # declared as outputs so they persist across grid steps —
            # only the (n, n)-sized diagnostics ever reach HBM
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((theta_w, n), jnp.float32),
        ),
        interpret=resolve_interpret(interpret),
    )(grads)
    return agg[0, :d], sel[0], scores[0]


# ---------------------------------------------------------------------------
# the pair kernel (multi-leaf tree path): select + coordinate in one pass
# ---------------------------------------------------------------------------

def _make_pair_kernel(n: int, f: int, mode: str):
    if mode in COORD_MODES:
        def kernel(g_ref, agg_ref):
            x = g_ref[...].astype(jnp.float32)
            agg_ref[...] = _combine_tile(x, None, n, f, mode)
    else:
        def kernel(g_ref, w_ref, agg_ref):
            x = g_ref[...].astype(jnp.float32)
            agg_ref[...] = _combine_tile(x, w_ref[...], n, f, mode)
    return kernel


@functools.partial(jax.jit, static_argnames=("f", "mode", "block_d",
                                             "interpret"))
def fused_coordinate(stack: jnp.ndarray, weights: Optional[jnp.ndarray],
                     f: int, *, mode: str = "bulyan-krum",
                     block_d: int = 2048,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Selection-combine + coordinate phase of one leaf, one kernel.

    The multi-leaf half of the fused lowering: distances come from the
    per-leaf ``pairwise_gram_partial`` accumulation (or the engine's
    backend closure), :func:`select_weights` turns them into a weight
    matrix once, and this kernel applies weight-gather and coordinate
    reduction per leaf — the ``(theta, d)`` gather of the unfused
    ``bulyan_select`` path never materializes.

    Args:
      stack: ``(n, d)`` worker-stacked leaf slab, any float dtype.
      weights: ``(theta_w, n)`` f32 selection weights from
        :func:`select_weights`; ``None`` for the coordinate-only modes
        (which sort the worker rows directly).
      f: Byzantine bound (static).
      mode: one of :data:`FUSED_MODES`.
      block_d: VMEM tile width along d.
      interpret: ``None`` resolves per backend.

    Returns:
      ``(d,)`` f32 aggregated coordinates of this leaf.
    """
    n, d = stack.shape
    _check_mode_shape(n, f, mode)
    coord_only = mode in COORD_MODES
    if coord_only != (weights is None):
        raise ValueError(
            f"mode {mode!r} {'takes no' if coord_only else 'needs'} "
            f"selection weights")
    block_d = min(block_d, max(d, 128))
    pad = (-d) % block_d
    if pad:
        stack = jnp.pad(stack, ((0, 0), (0, pad)))
    dp = stack.shape[1]
    in_specs = [pl.BlockSpec((n, block_d), lambda i: (0, i))]
    operands = [stack]
    if not coord_only:
        theta_w = _weight_rows(n, f, mode)
        in_specs.append(pl.BlockSpec((theta_w, n), lambda i: (0, 0)))
        operands.append(weights.astype(jnp.float32))
    out = pl.pallas_call(
        _make_pair_kernel(n, f, mode),
        grid=(dp // block_d,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(*operands)
    return out[0, :d]
