"""One-command adversarial self-audit: corner sweep + leeway gate.

The entry CI's ``audit`` job runs (and the local pre-merge check):

    PYTHONPATH=src python scripts/run_audit.py            # quick grid
    PYTHONPATH=src python scripts/run_audit.py --full     # whole grid
    PYTHONPATH=src python scripts/run_audit.py --rebaseline

* the corner sweep (``repro.audit.sweep``) walks every registered rule
  x attack x (n, f, tau, schedule) corner;
* the leeway meter (``repro.audit.leeway``) re-measures the ε-poisoning
  margins over the dimension ladder and certifies the scaling slopes
  against ``benchmarks/artifacts/leeway_baseline.json``.

``--rebaseline`` rewrites the baseline artifact from the current tree
(review the diff — a margin that moved by more than the gate's ratio
means aggregation behavior changed).  Exit status is the total number
of violations.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

BASELINE = REPO / "benchmarks" / "artifacts" / "leeway_baseline.json"


def main(argv=None) -> int:
    """Run both audit gates against the checked-in baseline.

    Args:
      argv: command-line arguments (``None`` = ``sys.argv[1:]``):
        ``--full`` runs the whole sweep grid instead of the CI quick
        grid, ``--rebaseline`` rewrites the baseline artifact,
        ``--seed`` reseeds the sweep's synthetic stacks.

    Returns:
      Process exit code — the total violation count across both gates.
    """
    from repro.audit import leeway, sweep

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--full", action="store_true",
                    help="the whole sweep grid (CI runs --quick)")
    ap.add_argument("--rebaseline", action="store_true",
                    help="rewrite the checked-in leeway baseline")
    ap.add_argument("--seed", type=int, default=0,
                    help="sweep PRNG seed (the leeway ladder keeps its "
                         "own fixed seed: the artifact must match the "
                         "baseline)")
    args = ap.parse_args(argv)

    failures = sweep.main(([] if args.full else ["--quick"])
                          + ["--seed", str(args.seed)])
    leeway_args = []
    if args.rebaseline:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        leeway_args += ["--out", str(BASELINE)]
    elif BASELINE.exists():
        leeway_args += ["--baseline", str(BASELINE)]
    failures += leeway.main(leeway_args)
    print(f"run_audit: {failures} total violations", flush=True)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
