"""Append generated dry-run + roofline tables to EXPERIMENTS.md."""
import subprocess, sys, os
os.chdir(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
env = dict(os.environ); env["PYTHONPATH"] = "src"
def gen(dirpath, mode):
    return subprocess.run([sys.executable, "-m", "repro.launch.summarize",
                           "--dir", dirpath, "--mode", mode],
                          capture_output=True, text=True, env=env).stdout
md = open("EXPERIMENTS.md").read()
marker = "## Generated tables"
md = md[:md.index(marker) + len(marker)]
md += "\n\n### Roofline — naive baseline (single-pod 16x16, rolled-scan convention)\n\n"
md += gen("artifacts/dryrun", "roofline").split("\n", 2)[2]
md += "\n\n### Roofline — optimized (scatter MoE + Megatron rules + attn batch-shard)\n\n"
md += gen("artifacts/dryrun_opt", "roofline").split("\n", 2)[2]
md += "\n\n### Dry-run detail — optimized, both meshes\n\n"
md += gen("artifacts/dryrun_opt", "dryrun").split("\n", 2)[2]
open("EXPERIMENTS.md", "w").write(md)
print("tables regenerated")
