"""Docs lint: keep README.md / docs/*.md honest.

Three checks over every markdown file given (default: README.md and
docs/**/*.md from the repo root):

  1. every fenced ``python`` code block must *compile* (syntax);
  2. every ``from repro... import X`` / ``import repro...`` line inside a
     python block must resolve against the installed tree (so renames
     break the docs loudly);
  3. every repo-relative path mentioned in the text (src/..., docs/...,
     examples/..., benchmarks/..., tests/..., scripts/...) must exist.

Exit status is the number of failures; run from CI as
``PYTHONPATH=src python scripts/docs_lint.py``.
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys
from typing import Iterable, List, Tuple

REPO = pathlib.Path(__file__).resolve().parent.parent

_FENCE_RE = re.compile(r"```(\w+)?\n(.*?)```", re.DOTALL)
_IMPORT_RE = re.compile(
    r"^\s*(?:from\s+(repro[\w.]*)\s+import\s+(\([^)]*\)|[\w, ]+)"
    r"|import\s+(repro[\w.]*))", re.MULTILINE)
_PATH_RE = re.compile(
    r"\b((?:src|docs|examples|benchmarks|tests|scripts)/[\w./-]+\.\w+)")


def code_blocks(text: str) -> Iterable[Tuple[str, str]]:
    """Yield ``(language, body)`` for every fenced block."""
    for m in _FENCE_RE.finditer(text):
        yield (m.group(1) or "", m.group(2))


def lint_file(path: pathlib.Path) -> List[str]:
    """All failures for one markdown file, as printable strings."""
    errors: List[str] = []
    text = path.read_text()
    try:
        rel = path.relative_to(REPO)
    except ValueError:  # explicit argument outside the repo root
        rel = path

    for lang, body in code_blocks(text):
        if lang != "python":
            continue
        try:
            compile(body, str(rel), "exec")
        except SyntaxError as e:
            errors.append(f"{rel}: python block does not compile: {e}")
            continue
        for m in _IMPORT_RE.finditer(body):
            module = m.group(1) or m.group(3)
            try:
                mod = importlib.import_module(module)
            except Exception as e:
                errors.append(f"{rel}: import {module} failed: {e}")
                continue
            for name in (m.group(2) or "").strip("()").split(","):
                name = name.strip()
                if name and not hasattr(mod, name):
                    errors.append(
                        f"{rel}: {module} has no symbol {name!r}")

    for m in _PATH_RE.finditer(text):
        target = REPO / m.group(1)
        if not target.exists():
            errors.append(f"{rel}: referenced path {m.group(1)} missing")
    return errors


def main(argv: List[str]) -> int:
    files = [(REPO / a).resolve() for a in argv] or (
        [REPO / "README.md"] + sorted((REPO / "docs").glob("**/*.md")))
    failures: List[str] = []
    for f in files:
        if not f.exists():
            failures.append(f"{f}: file missing")
            continue
        failures.extend(lint_file(f))
    for line in failures:
        print(f"docs-lint: {line}", file=sys.stderr)
    print(f"docs-lint: {len(files)} files, {len(failures)} failures")
    return len(failures)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
