"""Docs lint: keep README.md / docs/*.md (and module docstrings) honest.

Three checks over every markdown file given (default: README.md and
docs/**/*.md from the repo root):

  1. every fenced ``python`` code block must *compile* (syntax);
  2. every ``from repro... import X`` / ``import repro...`` line inside a
     python block must resolve against the installed tree (so renames
     break the docs loudly);
  3. every repo-relative path mentioned in the text (src/..., docs/...,
     examples/..., benchmarks/..., tests/..., scripts/...) must exist.

``--modules mod [mod ...]`` switches to the *module audit* instead: each
named python module must export a sorted ``__all__``, every exported
symbol must carry a docstring, and every exported function taking
arguments must document them with ``Args:`` / ``Returns:`` sections.

Exit status is the number of failures; run from CI as
``PYTHONPATH=src python scripts/docs_lint.py`` and
``... docs_lint.py --modules repro.agg.registry ...``.
"""
from __future__ import annotations

import importlib
import inspect
import pathlib
import re
import sys
from typing import Iterable, List, Tuple

REPO = pathlib.Path(__file__).resolve().parent.parent

_FENCE_RE = re.compile(r"```(\w+)?\n(.*?)```", re.DOTALL)
_IMPORT_RE = re.compile(
    r"^\s*(?:from\s+(repro[\w.]*)\s+import\s+(\([^)]*\)|[\w, ]+)"
    r"|import\s+(repro[\w.]*))", re.MULTILINE)
_PATH_RE = re.compile(
    r"\b((?:src|docs|examples|benchmarks|tests|scripts)/[\w./-]+\.\w+)")


def code_blocks(text: str) -> Iterable[Tuple[str, str]]:
    """Yield ``(language, body)`` for every fenced block."""
    for m in _FENCE_RE.finditer(text):
        yield (m.group(1) or "", m.group(2))


def lint_file(path: pathlib.Path) -> List[str]:
    """All failures for one markdown file, as printable strings."""
    errors: List[str] = []
    text = path.read_text()
    try:
        rel = path.relative_to(REPO)
    except ValueError:  # explicit argument outside the repo root
        rel = path

    for lang, body in code_blocks(text):
        if lang != "python":
            continue
        try:
            compile(body, str(rel), "exec")
        except SyntaxError as e:
            errors.append(f"{rel}: python block does not compile: {e}")
            continue
        for m in _IMPORT_RE.finditer(body):
            module = m.group(1) or m.group(3)
            try:
                mod = importlib.import_module(module)
            except Exception as e:
                errors.append(f"{rel}: import {module} failed: {e}")
                continue
            for name in (m.group(2) or "").strip("()").split(","):
                name = name.strip()
                if name and not hasattr(mod, name):
                    errors.append(
                        f"{rel}: {module} has no symbol {name!r}")

    for m in _PATH_RE.finditer(text):
        target = REPO / m.group(1)
        if not target.exists():
            errors.append(f"{rel}: referenced path {m.group(1)} missing")
    return errors


def audit_module(modname: str) -> List[str]:
    """All docstring-contract failures for one python module.

    The contract (the ``repro.agg`` acceptance bar): the module exports
    a sorted, duplicate-free ``__all__``; every exported symbol has a
    docstring; every exported *function* with parameters documents them
    under an ``Args:`` section and its result under ``Returns:``.
    """
    errors: List[str] = []
    try:
        mod = importlib.import_module(modname)
    except Exception as e:
        return [f"{modname}: import failed: {e}"]
    exported = getattr(mod, "__all__", None)
    if not exported:
        return [f"{modname}: missing __all__"]
    if list(exported) != sorted(set(exported)):
        errors.append(f"{modname}: __all__ unsorted or duplicated")
    for name in exported:
        obj = getattr(mod, name, None)
        if obj is None:
            errors.append(f"{modname}.{name}: in __all__ but missing")
            continue
        doc = inspect.getdoc(obj)
        if not doc:
            errors.append(f"{modname}.{name}: no docstring")
            continue
        if inspect.isfunction(obj):
            params = [p for p in
                      inspect.signature(obj).parameters.values()
                      if p.name != "self"]
            if params and "Args:" not in doc:
                errors.append(f"{modname}.{name}: no Args: section")
            if "Returns:" not in doc:
                errors.append(f"{modname}.{name}: no Returns: section")
    return errors


def main(argv: List[str]) -> int:
    failures: List[str] = []
    if argv and argv[0] == "--modules":
        mods = argv[1:]
        for m in mods:
            failures.extend(audit_module(m))
        for line in failures:
            print(f"docs-lint: {line}", file=sys.stderr)
        print(f"docs-lint: {len(mods)} modules, {len(failures)} failures")
        return len(failures)
    files = [(REPO / a).resolve() for a in argv] or (
        [REPO / "README.md"] + sorted((REPO / "docs").glob("**/*.md")))
    for f in files:
        if not f.exists():
            failures.append(f"{f}: file missing")
            continue
        failures.extend(lint_file(f))
    for line in failures:
        print(f"docs-lint: {line}", file=sys.stderr)
    print(f"docs-lint: {len(files)} files, {len(failures)} failures")
    return len(failures)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
