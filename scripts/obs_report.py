"""Defense report from aggregation forensics: is the paper's attack live?

Two modes:

    PYTHONPATH=src python scripts/obs_report.py                 # demo
    PYTHONPATH=src python scripts/obs_report.py --quick         # CI smoke
    PYTHONPATH=src python scripts/obs_report.py --input run.jsonl

The **demo** mode trains the MNIST-scale flat reference twice with
telemetry on — once clean, once under the paper's omniscient attack —
drains both forensics rings, and prints the side-by-side detector
report: selection entropy (collapses under the attack), the suspicion
ranking (Byzantine rows must rank first when the defense holds), and
the ε-margin trajectory.  ``--quick`` shrinks the run for the CI smoke
job; exit status is 0 iff the attacked run reproduces the
entropy-collapse signature relative to the clean one AND the suspicion
ranking under a *defended* rule places a Byzantine row on top.

The **input** mode replays the same report over a JSONL file of drained
records (one ``repro.obs.export.write_jsonl`` row per recorded step,
plus an optional ``selection_frequency`` row) — the offline path for
rings exported from a real run.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def _train(gar: str, attack: str, n_workers: int, f: int, steps: int,
           seed: int = 0):
    """One telemetry-on flat training run; returns the drained report."""
    import jax

    from repro.data import ByzantineBatcher
    from repro.models import simple
    from repro.optim import get_optimizer
    from repro.training import ByzantineSpec, ByzantineTrainer

    def loss_fn(params, x, y):
        return simple.classification_loss(
            simple.mnist_mlp_forward(params, x), y, params)

    kwargs = (("gar_name", gar),) if attack == "omniscient_lp" else ()
    spec = ByzantineSpec(n_workers=n_workers, f=f, gar=gar, attack=attack,
                         attack_kwargs=kwargs, telemetry=True)
    trainer = ByzantineTrainer(
        loss_fn, simple.init_mnist_mlp(jax.random.PRNGKey(seed)),
        get_optimizer("sgd", 0.05), spec, seed=seed)
    trainer.run(ByzantineBatcher("mnist", spec.n_honest, 32), steps)
    return trainer.telemetry()


def _report(tag: str, drained: dict) -> dict:
    """Detector summary of one drained forensics ring."""
    from repro.obs.detect import (margin_trajectory, selection_collapsed,
                                  selection_entropy, suspicion_scores)

    freq = np.asarray(drained["selection_frequency"], np.float64)
    records = drained["records"]
    suspicion = suspicion_scores(records, freq)
    margins = margin_trajectory(records)
    return {
        "tag": tag,
        "recorded_steps": len(records),
        "pushed": int(drained["pushed"]),
        "selection_entropy": selection_entropy(freq),
        "collapsed": bool(selection_collapsed(freq)),
        "selection_frequency": freq.round(4).tolist(),
        "suspicion": suspicion.round(4).tolist(),
        "most_suspect": int(np.argmax(suspicion)) if suspicion.size else -1,
        "margin_mean": float(margins.mean()) if margins.size else 1.0,
        "margin_min": float(margins.min()) if margins.size else 1.0,
    }


def _print_report(rep: dict) -> None:
    print(f"--- {rep['tag']} ---")
    print(f"  recorded steps      {rep['recorded_steps']} "
          f"(pushed {rep['pushed']})")
    print(f"  selection entropy   {rep['selection_entropy']:.4f} "
          f"{'[COLLAPSED]' if rep['collapsed'] else '[healthy]'}")
    print(f"  selection freq      {rep['selection_frequency']}")
    print(f"  suspicion           {rep['suspicion']}")
    print(f"  most suspect row    {rep['most_suspect']}")
    print(f"  eps-margin          mean {rep['margin_mean']:.4f}  "
          f"min {rep['margin_min']:.4f}")


def _input_mode(path: str, out: str | None) -> int:
    """Report over an exported JSONL of drained records."""
    from repro.obs.export import read_jsonl, write_jsonl

    rows = read_jsonl(path)
    records = [r for r in rows if "dist_to_agg" in r]
    freq_rows = [r for r in rows if "selection_frequency" in r]
    if freq_rows:
        freq = np.asarray(freq_rows[-1]["selection_frequency"], np.float64)
    elif records:
        sel = np.sum([np.asarray(r["selected"], np.float64)
                      for r in records], axis=0)
        freq = sel / max(sel.sum(), 1e-12)
    else:
        freq = np.zeros((0,), np.float64)
    rep = _report(path, {"records": records, "selection_frequency": freq,
                         "pushed": len(records)})
    _print_report(rep)
    if out:
        write_jsonl(out, [rep])
        print(f"report written to {out}")
    return 0


def main(argv=None) -> int:
    """Print the clean-vs-attacked defense report (demo) or replay a file.

    Args:
      argv: command-line arguments (``None`` = ``sys.argv[1:]``):
        ``--input`` replays an exported JSONL instead of training,
        ``--quick`` shrinks the demo for CI, ``--gar``/``--attack``/
        ``--steps`` parameterize the demo runs, ``--out`` writes the
        JSONL report artifact.

    Returns:
      Process exit status — 0 when the attacked demo run shows the
      entropy-collapse signature and the defended suspicion ranking
      blames a Byzantine row, 1 otherwise.
    """
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--input", default=None,
                    help="JSONL of drained records to report on "
                         "(skips the demo training)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer steps, smaller committee")
    ap.add_argument("--gar", default="krum",
                    help="defended GAR of the demo runs")
    ap.add_argument("--attack", default="omniscient_lp",
                    help="attack of the poisoned demo run")
    ap.add_argument("--steps", type=int, default=None,
                    help="demo training steps (default 12, --quick 4)")
    ap.add_argument("--out", default=None, help="JSONL report path")
    args = ap.parse_args(argv)

    if args.input:
        return _input_mode(args.input, args.out)

    steps = args.steps or (4 if args.quick else 12)
    n_workers, f = (9, 2) if args.quick else (15, 3)
    print(f"obs_report demo: gar={args.gar} attack={args.attack} "
          f"n={n_workers} f={f} steps={steps}")
    clean = _report("clean", _train(args.gar, "none", n_workers, 0, steps))
    attacked = _report(
        f"attacked ({args.attack})",
        _train(args.gar, args.attack, n_workers, f, steps))
    # the suspicion ranking needs a *defended* run: under the successful
    # omniscient attack the winning crafted row sits ON the aggregate
    # (zero distance, zero starvation), so blame only lands on the
    # Byzantine tail when the rule actually rejects it
    defended = _report("defended (signflip)",
                       _train(args.gar, "signflip", n_workers, f, steps))
    _print_report(clean)
    _print_report(attacked)
    _print_report(defended)

    # the paper's signature: the attacker monopolizes selection, so the
    # attacked run's entropy drops strictly below the clean run's
    collapse = (attacked["selection_entropy"]
                < clean["selection_entropy"] - 1e-9)
    blamed = defended["most_suspect"] >= n_workers - f
    print(f"entropy collapse reproduced: {collapse} "
          f"({clean['selection_entropy']:.4f} -> "
          f"{attacked['selection_entropy']:.4f})")
    print(f"defended suspicion blames Byzantine row: {blamed} "
          f"(row {defended['most_suspect']}, byz rows "
          f">= {n_workers - f})")
    if args.out:
        from repro.obs.export import write_jsonl
        write_jsonl(args.out, [clean, attacked, defended])
        print(f"report written to {args.out}")
    return 0 if (collapse and blamed) else 1


if __name__ == "__main__":
    raise SystemExit(main())
